#!/usr/bin/env bash
# Container entrypoint for the LP RPC server: pin the measured-fast
# runtime environment, then exec `python -m repro.serve_lp.rpc`.
#
# Every export here is overridable from the outside environment
# (`VAR=... serve_entrypoint.sh` wins); CLI flags pass through, e.g.
#
#   scripts/serve_entrypoint.sh --port 8080 --target-p99-ms 50
set -euo pipefail

# tcmalloc beats glibc malloc on the serving hot path (flush-buffer
# churn + XLA host allocations); skip silently where it isn't baked in.
TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -z "${LD_PRELOAD:-}" && -f "$TCMALLOC" ]]; then
    export LD_PRELOAD="$TCMALLOC"
    # and keep it quiet about the large flush-buffer arenas
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
fi

# Silence the TF/XLA C++ startup chatter that would interleave with the
# server's own stdout lines.
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# On CPU hosts, split the host platform into multiple XLA devices so
# flushes shard the same way they do on a multi-chip accelerator.
# Leave unset for real TPU/GPU machines (their device count is real).
if [[ -n "${SERVE_HOST_DEVICES:-}" ]]; then
    export XLA_FLAGS="--xla_force_host_platform_device_count=${SERVE_HOST_DEVICES} ${XLA_FLAGS:-}"
fi

# Multi-host seam: set SERVE_COORDINATOR (host:port of process 0) plus
# SERVE_NUM_PROCESSES / SERVE_PROCESS_ID to join a multi-host serving
# fleet — the module calls jax.distributed.initialize before touching
# devices, after which flush layouts can span hosts via the reserved
# "hosts" mesh axis (see repro/serve_lp/mesh_layout.py).  Unset on
# single-host launches; nothing else changes.
if [[ -n "${SERVE_COORDINATOR:-}" ]]; then
    : "${SERVE_NUM_PROCESSES:?SERVE_COORDINATOR set but SERVE_NUM_PROCESSES missing}"
    : "${SERVE_PROCESS_ID:?SERVE_COORDINATOR set but SERVE_PROCESS_ID missing}"
fi

# x64 policy: allow fp64 specs (`--method` + float64 dtype) without
# forcing every default array to fp64.
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-1}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

cd "$(dirname "$0")/.."
export PYTHONPATH="${PYTHONPATH:+$PYTHONPATH:}$(pwd)/src"

# Containers log to collectors, not humans: default to structured JSON
# lines (one object per line, trace_id/tenant bound from the request
# context).  A caller passing its own --log-format wins.
LOG_FORMAT_ARGS=(--log-format json)
for arg in "$@"; do
    [[ "$arg" == --log-format* ]] && LOG_FORMAT_ARGS=()
done

exec /usr/bin/env python3 -m repro.serve_lp.rpc "${LOG_FORMAT_ARGS[@]}" "$@"
