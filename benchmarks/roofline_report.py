"""Render EXPERIMENTS.md tables from benchmarks/results/dryrun.json.

    PYTHONPATH=src python -m benchmarks.roofline_report
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun.json"


def fmt_t(s):
    if s is None:
        return "-"
    return f"{s*1e3:.1f}ms" if s < 10 else f"{s:.2f}s"


def main():
    all_recs = json.loads(RESULTS.read_text())
    variants = [r for r in all_recs
                if r.get("variant", "baseline") != "baseline"]
    recs = [r for r in all_recs
            if r.get("variant", "baseline") == "baseline"]
    single = [r for r in recs if not r.get("multi_pod")]

    print("### Dry-run status (all cells must compile)\n")
    print("| arch | shape | 16x16 | 2x16x16 | compile_s (1pod/2pod) |")
    print("|---|---|---|---|---|")
    by_key = {(r["arch"], r["shape"], r.get("multi_pod", False)): r
              for r in recs}
    archs = sorted({r["arch"] for r in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    n_ok = n_skip = n_fail = 0
    for a in archs:
        for s in shapes:
            r1 = by_key.get((a, s, False), {})
            r2 = by_key.get((a, s, True), {})
            st1, st2 = r1.get("status", "?"), r2.get("status", "?")
            for st in (st1, st2):
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "FAIL"
            print(f"| {a} | {s} | {st1} | {st2} | "
                  f"{r1.get('compile_s','-')}/{r2.get('compile_s','-')} |")
    print(f"\nok={n_ok} skipped={n_skip} FAILED={n_fail}\n")

    print("### Roofline (single-pod 16x16, per-device terms)\n")
    print("| arch | shape | t_compute | t_memory(fused) | t_mem(unfused) "
          "| t_collective | bottleneck | useful | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in single:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        f = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(f['t_compute_s'])} | "
              f"{fmt_t(f['t_memory_s'])} | "
              f"{fmt_t(f.get('t_memory_unfused_s'))} | "
              f"{fmt_t(f['t_collective_s'])} | {f['bottleneck']} | "
              f"{f['useful_ratio']:.3f} | {f['roofline_fraction']:.3f} |")

    if variants:
        print("\n### Perf variants (baseline vs optimized, single pod)\n")
        print("| arch | shape | variant | t_coll base->opt | "
              "frac base->opt | verdict |")
        print("|---|---|---|---|---|---|")
        base = {(r["arch"], r["shape"]): r for r in single
                if r.get("roofline")}
        for r in variants:
            if r.get("status") != "ok" or "roofline" not in r:
                print(f"| {r['arch']} | {r['shape']} | "
                      f"{r.get('variant')} | - | - | FAILED |")
                continue
            b = base.get((r["arch"], r["shape"]))
            if not b:
                continue
            bf, of = b["roofline"], r["roofline"]
            verdict = ("confirmed" if of["roofline_fraction"] >
                       bf["roofline_fraction"] * 1.05 else
                       "refuted" if of["roofline_fraction"] <
                       bf["roofline_fraction"] * 0.95 else "neutral")
            print(f"| {r['arch']} | {r['shape']} | {r['variant']} | "
                  f"{fmt_t(bf['t_collective_s'])} -> "
                  f"{fmt_t(of['t_collective_s'])} | "
                  f"{bf['roofline_fraction']:.3f} -> "
                  f"{of['roofline_fraction']:.3f} | {verdict} |")


if __name__ == "__main__":
    main()
