"""Paper Figure 4: solve time vs batch amount at fixed LP size.

Reproduces the paper's central scaling claim: RGB time grows sub-
linearly with batch (vectorised work fills idle lanes) while the CPU
per-problem loop grows linearly."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import normalize_batch, random_feasible_lp, shuffle_batch
from repro.solver import SolverSpec
from benchmarks.fig3_lp_size import scipy_batch

SIZES = (64,)
BATCHES = (64, 256, 1024, 4096, 16384)


def run(full: bool = False):
    rows = []
    batches = BATCHES if full else (64, 512, 4096)
    for m in SIZES:
        for B in batches:
            lp = shuffle_batch(jax.random.key(2), normalize_batch(
                random_feasible_lp(jax.random.key(B * 7 + m), B, m)))
            for method in ("naive", "rgb"):
                solver = SolverSpec(backend=method,
                                    normalize=False).build()
                dt = time_fn(solver.solve, lp)
                rows.append(emit(f"fig4/m{m}/b{B}/{method}", dt,
                                 f"per_lp_us={dt/B*1e6:.2f}"))
            if B <= 1024 or full:
                dt = scipy_batch(lp)
                rows.append(emit(f"fig4/m{m}/b{B}/scipy-highs", dt,
                                 f"per_lp_us={dt/B*1e6:.2f}"))
    return rows


if __name__ == "__main__":
    run(full=True)
