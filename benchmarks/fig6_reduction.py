"""Paper Figure 6: accumulation-strategy performance vs contention.

The paper compares shared-memory atomics / global atomics / CUB
segmented reduction for the u_left/u_right folds.  TPUs have no atomics
(DESIGN.md section 2), so the candidates are the strategies available to
a vector machine:

  * masked-min  — dense jnp.min over a masked (contention-wide) axis
    (what the RGB kernel uses; the atomicMin analogue),
  * segment-min — jax.ops.segment_min scatter-style reduction,
  * sort-min    — sort by segment then segmented scan.

Contention = elements reducing into one output (the paper's x-axis,
2..512)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn

N = 1 << 16


def run(full: bool = False):
    rows = []
    contentions = (2, 8, 32, 128, 512) if full else (2, 32, 512)
    key = jax.random.key(0)
    x = jax.random.uniform(key, (N,))
    for c in contentions:
        n_seg = N // c
        seg = jnp.repeat(jnp.arange(n_seg), c)

        def masked_min(v):
            return jnp.min(v.reshape(n_seg, c), axis=1)

        def segment_min(v):
            return jax.ops.segment_min(v, seg, num_segments=n_seg)

        def sort_min(v):
            order = jnp.argsort(seg, stable=True)
            vs = v[order]
            return jnp.minimum.reduceat(vs, jnp.arange(0, N, c)) \
                if False else jax.ops.segment_min(vs, seg[order], n_seg)

        for name, fn in (("masked-min", masked_min),
                         ("segment-min", segment_min),
                         ("sort-min", sort_min)):
            f = jax.jit(fn)
            dt = time_fn(f, x, iters=5)
            rows.append(emit(f"fig6/contention{c}/{name}", dt,
                             f"elems_per_us={N/(dt*1e6):.0f}"))
    return rows


if __name__ == "__main__":
    run(full=True)
