"""Packed-layout microbenchmark: what does pre-packing buy?

Three ways to solve the same batch stream through one Solver:

* ``aos``      — solve the AoS ``LPBatch`` (the solver packs inside the
  trace where the backend needs it);
* ``packed``   — pack once up front, solve the ``PackedLPBatch``
  repeatedly (the canonical serving shape: the layout prerequisite for
  double-buffered flushes);
* ``repack``   — re-pack the AoS batch *on every call* (the pre-refactor
  serving hot path, kept here as the regression baseline).

Emits one JSON row per (variant, backend) alongside the harness CSV
line, including the ``pack_calls`` each variant performed so the
no-repack claim is machine-checkable.  ``--smoke`` runs a CI-sized grid
and *asserts* that the pre-packed variant performs zero pack calls and
matches the AoS results bit-for-bit.

    python -m benchmarks.pack_layout          # quick grid
    python -m benchmarks.pack_layout --full   # paper-sized grid
    python -m benchmarks.pack_layout --smoke  # CI assertion mode
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import pack, pack_call_count, random_feasible_lp
from repro.solver import SolverSpec


def _specs(smoke: bool):
    specs = [("rgb", SolverSpec(backend="rgb"))]
    if smoke:
        specs.append(("kernel", SolverSpec(backend="kernel",
                                           interpret=True)))
    return specs


def run(full: bool = False, smoke: bool = False):
    if smoke:
        grid = [(64, 32)]
    elif full:
        grid = [(4096, 64), (4096, 512), (16384, 128)]
    else:
        grid = [(512, 64)]
    iters = 2 if smoke else 3
    rows = []
    for B, m in grid:
        lp = random_feasible_lp(jax.random.key(B + m), B, m)
        pb = pack(lp)
        for label, spec in _specs(smoke):
            solver = spec.build()
            variants = {
                "aos": lambda: solver.solve(lp),
                "packed": lambda: solver.solve(pb),
                "repack": lambda: solver.solve(pack(lp)),
            }
            results = {}
            for variant, fn in variants.items():
                n0 = pack_call_count()
                dt = time_fn(fn, warmup=1, iters=iters)
                n_calls = pack_call_count() - n0
                results[variant] = (dt, n_calls, fn())
                row = {
                    "bench": "pack_layout", "variant": variant,
                    "backend": label, "batch": B, "m": m,
                    "seconds": dt, "us_per_lp": dt / B * 1e6,
                    "pack_calls": n_calls,
                }
                print(json.dumps(row), flush=True)
                rows.append(emit(
                    f"pack_layout/b{B}/m{m}/{label}/{variant}", dt,
                    f"pack_calls={n_calls}"))
            if smoke:
                calls_packed = results["packed"][1]
                assert calls_packed == 0, (
                    f"pre-packed solve repacked {calls_packed}x on "
                    f"{label}")
                assert results["repack"][1] >= iters, (
                    "repack variant should pack per call")
                np.testing.assert_array_equal(
                    np.asarray(results["packed"][2].x),
                    np.asarray(results["aos"][2].x),
                    err_msg=f"packed != AoS on {label}")
    if smoke:
        print("pack_layout --smoke ok: pre-packed path does zero "
              "AoS->SoA repacks and matches AoS bit-for-bit")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run asserting the no-repack claim")
    args = ap.parse_args(argv)
    run(full=args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
