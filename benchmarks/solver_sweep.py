"""SolverSpec sweep: one batch, every backend, one JSON row each.

The unified front end makes "same problem, every backend, bit-for-bit
comparable" a one-liner, which is exactly what a perf trajectory needs:
each run times the identical batch through the full spec sweep and
emits machine-readable JSON rows (alongside the harness CSV line) that
later sessions can diff.
"""
from __future__ import annotations

import json

import jax

from benchmarks.common import emit, time_fn
from repro.core import random_feasible_lp
from repro.solver import SolverSpec


def sweep_specs(full: bool = False):
    """The canonical sweep: every backend, plus rgb tile/chunk tuning
    points when --full."""
    specs = [
        ("naive", SolverSpec(backend="naive", shuffle=True)),
        ("rgb", SolverSpec(backend="rgb", shuffle=True)),
        ("rgb-t8-c64", SolverSpec(backend="rgb", tile=8, chunk=64,
                                  shuffle=True)),
        ("kernel", SolverSpec(backend="kernel", interpret=True,
                              shuffle=True)),
    ]
    if full:
        specs += [
            ("rgb-t128", SolverSpec(backend="rgb", tile=128,
                                    shuffle=True)),
            ("rgb-t32-c64", SolverSpec(backend="rgb", tile=32, chunk=64,
                                       shuffle=True)),
        ]
    return specs


def run(full: bool = False):
    B, m = (4096, 256) if full else (512, 64)
    lp = random_feasible_lp(jax.random.key(42), B, m)
    rows = []
    for label, spec in sweep_specs(full):
        solver = spec.build()
        dt = time_fn(solver.solve, lp)
        sol = solver.solve(lp)
        # Report the geometry the solve actually ran (unset tile/chunk
        # are pinned per shape by the table/heuristic), not the spec's
        # None sentinels — the trajectory row must name its launch.
        ran = spec.resolve_for_shape(m, B)
        row = {
            "bench": "solver_sweep",
            "label": label,
            "backend": ran.backend,
            "tile": ran.tile,
            "chunk": ran.chunk,
            "batch": B,
            "m": m,
            "seconds": dt,
            "us_per_lp": dt / B * 1e6,
            "n_feasible": int(sol.feasible.sum()),
        }
        print(json.dumps(row), flush=True)
        rows.append(emit(f"solver_sweep/b{B}/m{m}/{label}", dt,
                         f"per_lp_us={dt/B*1e6:.2f}"))
    return rows


if __name__ == "__main__":
    run(full=True)
