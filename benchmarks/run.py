"""Benchmark harness entry point — one module per paper table/figure.

``python -m benchmarks.run``          quick pass (CI-sized)
``python -m benchmarks.run --full``   full sweep (paper-sized grids)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated names (fig3..fig7, serve, "
                         "solver_sweep, pack_layout, pdhg_crossover, "
                         "tune)")
    args = ap.parse_args()

    from benchmarks import (fig3_lp_size, fig4_batch, fig5_transfer,
                            fig6_reduction, fig7_naive_vs_rgb,
                            pack_layout, pdhg_crossover, serve_bench,
                            solver_sweep, tune_cli)
    figs = {
        "fig3": fig3_lp_size.run,
        "fig4": fig4_batch.run,
        "fig5": fig5_transfer.run,
        "fig6": fig6_reduction.run,
        "fig7": fig7_naive_vs_rgb.run,
        "serve": serve_bench.run,
        "solver_sweep": solver_sweep.run,
        "pack_layout": pack_layout.run,
        "pdhg_crossover": pdhg_crossover.run,
        "tune": tune_cli.run,
    }
    only = set(args.only.split(",")) if args.only else set(figs)
    print("name,us_per_call,derived")
    for name, fn in figs.items():
        if name in only:
            fn(full=args.full)


if __name__ == "__main__":
    main()
