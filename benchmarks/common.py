"""Shared benchmark utilities: wall-clock timing of jitted callables on
the host CPU (the measurable runtime in this container), CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds*1e6:.1f},{derived}"
    print(line, flush=True)
    return line
