"""Paper Figure 5: proportion of time spent moving data vs computing.

The paper measures CUDA managed-memory paging; the analogue here is
host->device transfer (jax.device_put of the constraint arrays) vs the
solve itself.  Reproduces the claim that as batch grows, transfer takes
an increasing share of end-to-end time (their bright-yellow region)."""
from __future__ import annotations


import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import (normalize_batch, pack, random_feasible_lp,
                        shuffle_batch)
from repro.solver import SolverSpec


def run(full: bool = False):
    rows = []
    grid = [(256, 64), (4096, 64), (16384, 64), (4096, 512)] if full else \
        [(256, 64), (4096, 64)]
    for B, m in grid:
        lp = shuffle_batch(jax.random.key(3), normalize_batch(
            random_feasible_lp(jax.random.key(B + m), B, m)))
        hostA = np.asarray(lp.A)
        hostb = np.asarray(lp.b)
        hostc = np.asarray(lp.c)
        pb = pack(lp)
        hostL = np.asarray(pb.L)

        def transfer():
            return (jax.device_put(hostA), jax.device_put(hostb),
                    jax.device_put(hostc))

        def transfer_packed():
            # The serving path's shape: one contiguous packed block
            # (plus the small c) instead of three AoS arrays.
            return (jax.device_put(hostL), jax.device_put(hostc))

        t_x = time_fn(transfer, iters=5)
        t_xp = time_fn(transfer_packed, iters=5)
        solver = SolverSpec(backend="rgb", normalize=False).build()
        t_c = time_fn(solver.solve, lp)
        frac = t_x / (t_x + t_c)
        rows.append(emit(f"fig5/b{B}/m{m}", t_x + t_c,
                         f"transfer_frac={frac:.3f}"))
        rows.append(emit(f"fig5/b{B}/m{m}/packed", t_xp + t_c,
                         f"transfer_frac={t_xp / (t_xp + t_c):.3f}"))
    return rows


if __name__ == "__main__":
    run(full=True)
