"""Perf hillclimb driver (EXPERIMENTS.md section Perf).

Re-runs the three selected (arch x shape) cells with the optimisation
variants and records them under distinct ``variant`` keys next to the
baseline records in dryrun.json:

  * granite-8b x train_4k  — most collective-bound train cell.
      variant ``vma-transpose``: check_rep=True (vma-tracked shard_map:
      the conservative psum-transposes in backward disappear).
  * granite-8b x decode_32k — worst roofline fraction among serve cells.
      variant ``weight-resident``: serving keeps the TP weight shard in
      HBM instead of FSDP-gathering per token.
  * arctic-480b x train_4k — the flagship MoE (the paper-representative
      large-batch cell: EP via replicated activations + psum).
      variant ``vma-transpose``.

Run AFTER the baseline sweep (shares dryrun.json):

    PYTHONPATH=src python -m benchmarks.hillclimb
"""
# NOTE: must run in its own process - forces 512 host devices via dryrun.
from repro.launch.dryrun import dryrun_cell, RESULTS_DIR  # noqa: E402

import json

CELLS = [
    ("granite-8b", "train_4k", "vma-transpose", {"check_rep": True}),
    ("granite-8b", "decode_32k", "weight-resident",
     {"weight_resident": True}),
    # arctic: check_rep=True produces WRONG MoE grads (vma x scatter bug,
    # see tests) — its optimization is the fused MoE+dense residual psum,
    # which is now the default code path; re-probing records it.
    ("arctic-480b", "train_4k", "fused-psum", {"weight_resident": False}),
    ("arctic-480b", "decode_32k", "fused-psum", {"weight_resident": False}),
    ("internlm2-20b", "train_4k", "vma-transpose", {"check_rep": True}),
]


def main():
    out = RESULTS_DIR / "dryrun.json"
    existing = json.loads(out.read_text()) if out.exists() else []
    keyed = {(r["arch"], r["shape"], r.get("multi_pod", False),
              r.get("variant", "baseline")): r for r in existing}
    import traceback
    for arch, shape, variant, kw in CELLS:
        try:
            rec = dryrun_cell(arch, shape, multi_pod=False, probe=True,
                              step_kwargs=kw, variant=variant)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "multi_pod": False,
                   "variant": variant, "status": "FAIL", "error": repr(e)}
        keyed[(arch, shape, False, variant)] = rec
    out.write_text(json.dumps(list(keyed.values()), indent=1))
    print(f"hillclimb variants written -> {out}")


if __name__ == "__main__":
    main()
