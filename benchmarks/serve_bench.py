"""Serving-layer benchmark: open-loop traffic through repro.serve_lp.

Emits one CSV row per traffic profile: us_per_call is mean end-to-end
request latency; derived packs throughput / p99 / padding / cache-hit
numbers.

The ``serve_shard_*`` profiles A/B the mesh (shard_map, uneven shards,
cross-bucket fusing) and legacy pmap flush paths on an
underfull-heterogeneous burst, and additionally print one ``JSON``
line each with launch counts, pad-waste fractions and the per-device
row totals.  Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to see the
multi-device layouts on a CPU host.

The burst profiles run under a ``repro.obs`` tracer, so their
``idle_frac`` numbers are *measured* from per-device ``device.solve``
spans (union of solve intervals per device over the traffic window)
rather than the old host-side ``device_idle_s_est`` gauge; the traced
rows carry the measurement in their ``JSON`` line.
"""
from __future__ import annotations

import json

from benchmarks.common import emit
from repro.serve_lp.bench import (BenchConfig, run_rpc_traffic,
                                  run_traffic, smoke_config)


def _shard_profile(sharding: str) -> BenchConfig:
    """Underfull-heterogeneous burst: requests spread over the full
    m-bucket ladder, so per-bucket occupancy stays well below
    max_batch and the fused/uneven machinery has real work to do."""
    cfg = BenchConfig(requests=240, rate=2000.0, m_min=8, m_max=1024,
                      max_batch=32, max_wait_s=0.005, check=8)
    cfg.open_loop = True
    cfg.sharding = sharding
    return cfg


def run(full: bool = False) -> None:
    profiles = {"serve_smoke": smoke_config()}
    # Saturating burst through the pipelined loop vs the stop-and-go
    # loop: the A/B that shows what overlapping assembly with in-flight
    # solves buys (inflight/overlap/idle come from the new gauges).
    burst = smoke_config()
    burst.open_loop = True
    burst.trace = True
    profiles["serve_burst_pipelined"] = burst
    stopgo = smoke_config()
    stopgo.open_loop = True
    stopgo.pipeline = False
    stopgo.trace = True
    profiles["serve_burst_stopgo"] = stopgo
    if full:
        profiles["serve_open_loop"] = BenchConfig(
            requests=2000, rate=5000.0, m_max=1024, max_batch=128,
            max_wait_s=0.02)
        profiles["serve_kernel"] = BenchConfig(
            requests=256, rate=2000.0, m_max=256, max_batch=64,
            method="kernel", check=4)
    # Mesh vs pmap flush-path A/B (same traffic, same seed): the mesh
    # path fuses underfull buckets into shared launches over only the
    # devices it needs, so it should show strictly fewer launches and
    # at-least-pmap throughput.
    profiles["serve_shard_mesh"] = _shard_profile("mesh")
    profiles["serve_shard_pmap"] = _shard_profile("pmap")
    shard_rows = {}
    for name, cfg in profiles.items():
        snap, _ = run_traffic(cfg, quiet=True)
        if name.startswith("serve_shard_"):
            row = {
                "profile": name,
                "sharding": cfg.sharding,
                "throughput_lps": round(snap["throughput_lps"], 1),
                "launches": snap["launches_total"],
                "flushes": snap["n_flushes"],
                "fused_flushes": snap["fused_flushes"],
                "fused_buckets": snap["fused_buckets"],
                "pad_waste_problems": round(
                    snap["padding_waste_problems"], 4),
                "pad_waste_cells": round(snap["padding_waste_cells"], 4),
                "rows_per_device": snap["rows_per_device"],
            }
            shard_rows[cfg.sharding] = row
            print("JSON " + json.dumps(row), flush=True)
        if "device_idle_frac" in snap:
            # Measured from device.solve spans (traced profile) —
            # supersedes the host-side estimate.
            idle = f"|idle_frac={snap['device_idle_frac']:.3f}"
            print("JSON " + json.dumps({
                "profile": name,
                "device_idle_frac": round(snap["device_idle_frac"], 4),
                "device_busy_s": round(snap["device_busy_s"], 4),
                "device_window_s": round(snap["device_window_s"], 4),
                "device_tracks": snap["device_tracks"],
                "trace_spans": snap["trace_spans"],
            }), flush=True)
        else:
            idle = f"|idle_s={snap['device_idle_s_est']:.3f}"
        emit(name, snap["latency_mean_ms"] / 1e3,
             f"lps={snap['throughput_lps']:.1f}"
             f"|p50ms={snap['latency_p50_ms']:.2f}"
             f"|p99ms={snap['latency_p99_ms']:.2f}"
             f"|waste_cells={snap['padding_waste_cells']:.3f}"
             f"|cache_hit={snap['cache']['hit_rate']:.3f}"
             f"|inflight_max={snap['inflight_max']}"
             f"|overlapped={snap['overlapped_dispatches']}"
             + idle +
             f"|launches={snap['launches_total']}"
             f"|fused={snap['fused_flushes']}")
    if len(shard_rows) == 2:
        mesh, pmap = shard_rows["mesh"], shard_rows["pmap"]
        print(f"[serve_bench] shard A/B: mesh {mesh['launches']} "
              f"launches @ {mesh['throughput_lps']:.1f} LPs/s vs pmap "
              f"{pmap['launches']} launches @ "
              f"{pmap['throughput_lps']:.1f} LPs/s", flush=True)
    # Same smoke traffic through the HTTP front end: what the network
    # layer (parse + admission + loop hop) adds over in-process submit,
    # plus the overload-phase shed rate.
    rpc_cfg = smoke_config()
    rpc_cfg.rpc = True
    rep = run_rpc_traffic(rpc_cfg, quiet=True)
    c, o = rep["closed_loop"], rep["overload"]
    emit("serve_rpc_http", c["p50_ms"] / 1e3,
         f"rps={c['rps']:.1f}"
         f"|p50ms={c['p50_ms']:.2f}"
         f"|p99ms={c['p99_ms']:.2f}"
         f"|errors={c['errors']}"
         f"|shed_rate={o['shed_rate']:.3f}"
         f"|retry_after={int(o['retry_after_on_429'])}")
