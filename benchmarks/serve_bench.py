"""Serving-layer benchmark: open-loop traffic through repro.serve_lp.

Emits one CSV row per traffic profile: us_per_call is mean end-to-end
request latency; derived packs throughput / p99 / padding / cache-hit
numbers.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.serve_lp.bench import (BenchConfig, run_rpc_traffic,
                                  run_traffic, smoke_config)


def run(full: bool = False) -> None:
    profiles = {"serve_smoke": smoke_config()}
    # Saturating burst through the pipelined loop vs the stop-and-go
    # loop: the A/B that shows what overlapping assembly with in-flight
    # solves buys (inflight/overlap/idle come from the new gauges).
    burst = smoke_config()
    burst.open_loop = True
    profiles["serve_burst_pipelined"] = burst
    stopgo = smoke_config()
    stopgo.open_loop = True
    stopgo.pipeline = False
    profiles["serve_burst_stopgo"] = stopgo
    if full:
        profiles["serve_open_loop"] = BenchConfig(
            requests=2000, rate=5000.0, m_max=1024, max_batch=128,
            max_wait_s=0.02)
        profiles["serve_kernel"] = BenchConfig(
            requests=256, rate=2000.0, m_max=256, max_batch=64,
            method="kernel", check=4)
    for name, cfg in profiles.items():
        snap, _ = run_traffic(cfg, quiet=True)
        emit(name, snap["latency_mean_ms"] / 1e3,
             f"lps={snap['throughput_lps']:.1f}"
             f"|p50ms={snap['latency_p50_ms']:.2f}"
             f"|p99ms={snap['latency_p99_ms']:.2f}"
             f"|waste_cells={snap['padding_waste_cells']:.3f}"
             f"|cache_hit={snap['cache']['hit_rate']:.3f}"
             f"|inflight_max={snap['inflight_max']}"
             f"|overlapped={snap['overlapped_dispatches']}"
             f"|idle_s={snap['device_idle_s_est']:.3f}")
    # Same smoke traffic through the HTTP front end: what the network
    # layer (parse + admission + loop hop) adds over in-process submit,
    # plus the overload-phase shed rate.
    rpc_cfg = smoke_config()
    rpc_cfg.rpc = True
    rep = run_rpc_traffic(rpc_cfg, quiet=True)
    c, o = rep["closed_loop"], rep["overload"]
    emit("serve_rpc_http", c["p50_ms"] / 1e3,
         f"rps={c['rps']:.1f}"
         f"|p50ms={c['p50_ms']:.2f}"
         f"|p99ms={c['p99_ms']:.2f}"
         f"|errors={c['errors']}"
         f"|shed_rate={o['shed_rate']:.3f}"
         f"|retry_after={int(o['retry_after_on_429'])}")
