"""Kernel-vs-PDHG crossover sweep: where does first-order win?

The exact Seidel backends are expected-O(batch * m) with a tiny
constant but scan every constraint per problem; restarted PDHG pays a
per-iteration matvec yet its iteration count is shape-independent, so
past some constraint count the first-order backend overtakes the exact
ones.  This sweep times both on the same packed batches over an ``m``
ladder into the thousands and emits one JSON row per (backend, m) —
the measured crossover is exactly what ``backend="auto"`` routes on
once ``benchmarks/tune_cli.py`` folds these shapes into the table.

``--smoke`` is the CI contract: a CI-sized sweep plus two asserts —
(1) PDHG *converges* (per-problem certificate from
``solve_pdhg_with_stats``, not just a feasible flag) at the largest
smoke ``m``, and (2) auto resolution actually selects pdhg with the
recorded schedule when a table says it is fastest at large ``m``.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import random_feasible_lp
from repro.core.packed import pack
from repro.pdhg import solve_pdhg_with_stats
from repro.solver import SolverSpec
from repro.tune.table import (TableEntry, TableKey, TuningTable,
                              current_device_kind, use_table)

SMOKE_MS = (64, 256, 1024)
FULL_MS = (64, 256, 1024, 2048, 4096, 8192)


def _assert_auto_routes_to_pdhg(m_big: int, batch: int) -> SolverSpec:
    """Synthetic-table check that auto routing can pick pdhg: with a
    table recording pdhg fastest at ``m_big`` (and kernel fastest at a
    small bucket), ``backend="auto"`` must resolve to pdhg there with
    the entry's (iter_block, restart_period) — and still route the
    small bucket to kernel."""
    kind = current_device_kind()
    mk = lambda be, mb, t, ch, us: TableEntry(  # noqa: E731
        TableKey(kind, be, "float32", mb, 0), tile=t, chunk=ch,
        us_per_lp=us)
    from repro.tune.table import M_BUCKET_BASE, bucket_pow2
    mb_small, mb_big = 64, bucket_pow2(m_big, M_BUCKET_BASE)
    table = TuningTable([
        mk("kernel", mb_small, 8, 0, 1.0),
        mk("pdhg", mb_small, 64, 512, 50.0),
        mk("kernel", mb_big, 8, 0, 900.0),
        mk("pdhg", mb_big, 128, 2048, 30.0),
    ])
    with use_table(table):
        small = SolverSpec(backend="auto").resolve_for_shape(64, batch)
        big = SolverSpec(backend="auto").resolve_for_shape(m_big, batch)
    assert small.backend == "kernel", (
        f"auto at m=64 picked {small.backend!r}, expected kernel")
    assert big.backend == "pdhg", (
        f"auto at m={m_big} picked {big.backend!r}, expected pdhg")
    assert (big.iter_block, big.restart_period) == (128, 2048), (
        f"auto did not pin the recorded pdhg schedule: "
        f"({big.iter_block}, {big.restart_period})")
    return big


def run(full: bool = False, smoke: bool = False):
    ms = FULL_MS if full else SMOKE_MS
    B = 256 if full else 64
    specs = [
        ("kernel", SolverSpec(backend="kernel")),
        ("pdhg", SolverSpec(backend="pdhg")),
    ]
    rows = []
    stats_at_biggest = None
    for m in ms:
        lp = random_feasible_lp(jax.random.key(7 * m + B), B, m)
        pb = pack(lp)
        for label, spec in specs:
            solver = spec.build()
            dt = time_fn(solver.solve, pb)
            sol = solver.solve(pb)
            ran = spec.resolve_for_shape(m, B)
            row = {
                "bench": "pdhg_crossover",
                "backend": label,
                "batch": B,
                "m": m,
                "seconds": dt,
                "us_per_lp": dt / B * 1e6,
                "n_feasible": int(np.asarray(sol.feasible).sum()),
            }
            if label == "pdhg":
                row["iter_block"] = ran.iter_block
                row["restart_period"] = ran.restart_period
            else:
                row["tile"] = ran.tile
                row["chunk"] = ran.chunk
            print(json.dumps(row), flush=True)
            rows.append(emit(f"pdhg_crossover/b{B}/m{m}/{label}", dt,
                             f"per_lp_us={dt/B*1e6:.2f}"))
        if m == ms[-1]:
            _, stats_at_biggest = solve_pdhg_with_stats(pb)
    if smoke:
        st = stats_at_biggest
        conv = np.asarray(st.converged)
        kkt = np.asarray(st.kkt)
        assert conv.all(), (
            f"pdhg failed to converge on {int((~conv).sum())}/{B} "
            f"problems at m={ms[-1]} (max kkt {kkt.max():.3e})")
        routed = _assert_auto_routes_to_pdhg(ms[-1], B)
        print(f"pdhg_crossover --smoke ok: pdhg converged {B}/{B} at "
              f"m={ms[-1]} (max kkt {kkt.max():.3e}); auto routed "
              f"m={ms[-1]} -> pdhg/ib{routed.iter_block}/"
              f"rp{routed.restart_period}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run asserting pdhg convergence and "
                         "auto routing")
    args = ap.parse_args(argv)
    run(full=args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
