"""Paper Figure 3: solve time vs LP size at fixed batch counts.

Compares NaiveRGB (divergence-emulating vmap), RGB (cooperative tiles)
and the scipy/HiGHS per-problem CPU loop (the mGLPK/CLP stand-in
available in this container).  CPU wall-clock; the qualitative claim
reproduced is the *scaling* separation: RGB flattens with m thanks to
randomised order + tile early-exit while the CPU loop grows linearly in
batch and the naive version pays the full divergence cost.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import normalize_batch, random_feasible_lp, shuffle_batch
from repro.solver import SolverSpec

BATCHES = (128, 2048)
SIZES = (8, 32, 128, 512, 2048)
SCIPY_CAP = 256  # per-problem python loop gets slow; cap and extrapolate


def scipy_batch(lp) -> float:
    from scipy.optimize import linprog
    import time as _t
    A = np.asarray(lp.A, np.float64)
    b = np.asarray(lp.b, np.float64)
    c = np.asarray(lp.c, np.float64)
    n = min(lp.batch, SCIPY_CAP)
    t0 = _t.perf_counter()
    for i in range(n):
        linprog(-c[i], A_ub=A[i], b_ub=b[i],
                bounds=[(-1e4, 1e4)] * 2, method="highs")
    dt = _t.perf_counter() - t0
    return dt * (lp.batch / n)


def run(full: bool = False):
    rows = []
    batches = BATCHES if full else (128,)
    sizes = SIZES if full else (8, 64, 512)
    for B in batches:
        for m in sizes:
            lp = shuffle_batch(jax.random.key(1), normalize_batch(
                random_feasible_lp(jax.random.key(B + m), B, m)))
            for method in ("naive", "rgb", "kernel"):
                solver = SolverSpec(
                    backend=method, normalize=False,
                    interpret=True if method == "kernel" else None,
                ).build()
                dt = time_fn(solver.solve, lp)
                rows.append(emit(f"fig3/b{B}/m{m}/{method}", dt,
                                 f"per_lp_us={dt/B*1e6:.2f}"))
            dt = scipy_batch(lp)
            rows.append(emit(f"fig3/b{B}/m{m}/scipy-highs", dt,
                             f"per_lp_us={dt/B*1e6:.2f}"))
    return rows


if __name__ == "__main__":
    run(full=True)
