"""Paper Figure 7: optimized-RGB speedup over NaiveRGB (kernel time only).

The divergence the paper's Fig. 1 illustrates is emulated exactly by the
vmap'd naive solver (cond -> select: every problem pays every re-solve);
the cooperative solver skips re-solves whenever a whole tile is
satisfied.  Also reports the randomisation ablation on the adversarial
ordering (worst-case O(m^2) -> expected O(m))."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import (adversarial_lp, normalize_batch,
                        random_feasible_lp, shuffle_batch)
from repro.solver import SolverSpec


VARIANTS = (
    # Block-size/chunk tuning as a SolverSpec sweep (paper section 5:
    # "tailoring block sizes to the expected LP size")
    ("rgb-t32", SolverSpec(backend="rgb", tile=32, chunk=0,
                           normalize=False)),   # paper-faithful warp tile
    ("rgb-t32-c64", SolverSpec(backend="rgb", tile=32, chunk=64,
                               normalize=False)),  # + chunked re-solve
    ("rgb-t8-c64", SolverSpec(backend="rgb", tile=8, chunk=64,
                              normalize=False)),  # + small tile
)


def run(full: bool = False):
    rows = []
    sizes = (32, 128, 512, 2048) if full else (32, 256)
    B = 1024
    for m in sizes:
        lp = shuffle_batch(jax.random.key(4), normalize_batch(
            random_feasible_lp(jax.random.key(m), B, m)))
        naive = SolverSpec(backend="naive", normalize=False).build()
        t_naive = time_fn(naive.solve, lp)
        rows.append(emit(f"fig7/b{B}/m{m}/naive", t_naive, ""))
        for label, spec in VARIANTS:
            t = time_fn(spec.build().solve, lp)
            rows.append(emit(f"fig7/b{B}/m{m}/{label}", t,
                             f"over_naive={t_naive/t:.2f}x"))

    # randomisation ablation (Seidel's expected-O(m) claim)
    m = 512 if full else 128
    adv = normalize_batch(adversarial_lp(256, m))
    solver = SolverSpec(backend="rgb", normalize=False).build()
    t_adv = time_fn(solver.solve, adv)
    shuf = shuffle_batch(jax.random.key(0), adv)
    t_shuf = time_fn(solver.solve, shuf)
    rows.append(emit(f"fig7/adversarial/m{m}", t_shuf,
                     f"shuffle_speedup={t_adv/t_shuf:.2f}x"))
    return rows


if __name__ == "__main__":
    run(full=True)
