"""Offline tuner: measure launch-geometry candidates, emit a table.

Runs :func:`repro.tune.tune` over a shape grid on the *current* device,
streams one JSON row per timed candidate (the perf-trajectory record),
and writes/refreshes a versioned :class:`~repro.tune.TuningTable`:

    python -m benchmarks.tune_cli                    # quick grid
    python -m benchmarks.tune_cli --full             # paper-sized grid
    python -m benchmarks.tune_cli --out tables/dev.json --merge
    python -m benchmarks.tune_cli --smoke            # CI assertion mode

``--merge`` folds the new measurements into an existing ``--out`` file
(faster entry wins), so repeated runs monotonically improve the table.
Point ``REPRO_TUNE_TABLE`` at the written file — or commit it over
``src/repro/tune/default_table.json`` — to make solvers use it.

``--smoke`` runs a tiny space and *asserts* the subsystem contract:
the table round-trips save -> load -> merge unchanged, and
``SolverSpec.resolve_for_shape`` resolves to a recorded entry when the
table is active.
"""
from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

from benchmarks.common import emit
from repro.solver import SolverSpec
from repro.tune import (TuningTable, current_device_kind, tune, use_table)

QUICK_SHAPES = [(32, 256), (128, 512)]
FULL_SHAPES = [(16, 1024), (32, 4096), (128, 4096), (256, 1024),
               (512, 1024), (1024, 512)]
SMOKE_SHAPES = [(16, 32)]


def _row_cb(rows):
    def on_result(r):
        row = {
            "bench": "tune", "device_kind": r.device_kind,
            "backend": r.candidate.backend, "tile": r.candidate.tile,
            "chunk": r.candidate.chunk, "m_pad": r.m_pad,
            "batch": r.batch, "dtype": r.dtype, "seconds": r.seconds,
            "us_per_lp": r.us_per_lp,
        }
        print(json.dumps(row), flush=True)
        rows.append(row)
        emit(f"tune/m{r.m_pad}/b{r.batch}/{r.candidate.label()}",
             r.seconds, f"us_per_lp={r.us_per_lp:.2f}")
    return on_result


def _smoke_assertions(table: TuningTable, shapes) -> None:
    # 1. the table round-trips load -> merge -> save bit-stably
    with tempfile.TemporaryDirectory() as td:
        p1 = Path(td) / "t1.json"
        table.save(p1)
        loaded = TuningTable.load(p1)
        assert loaded == table, "save -> load changed the table"
        merged = TuningTable().merge(loaded).merge(table)
        assert merged == table, "merge is not idempotent"
        p2 = merged.save(Path(td) / "t2.json")
        assert p2.read_text() == p1.read_text(), \
            "round-tripped JSON differs"
    # 2. resolution picks a recorded entry when the table is active
    m, batch = shapes[0]
    with use_table(table):
        spec = SolverSpec(backend="rgb").resolve_for_shape(m, batch)
        hit = table.lookup(backend="rgb", dtype="float32", m=m,
                           batch=batch)
        assert hit is not None, "tuner recorded no rgb entry"
        assert (spec.tile, spec.chunk) == (hit.tile, hit.chunk), (
            f"resolution picked ({spec.tile}, {spec.chunk}), table has "
            f"({hit.tile}, {hit.chunk})")
    # 3. explicit user values still win over the recorded entry
    with use_table(table):
        spec = SolverSpec(backend="rgb", tile=8,
                          chunk=0).resolve_for_shape(m, batch)
        assert (spec.tile, spec.chunk) == (8, 0), \
            "explicit tile/chunk lost to the table"
    print("tune_cli --smoke ok: table round-trips and resolution "
          "prefers recorded entries (explicit still wins)")


def run(full: bool = False, smoke: bool = False, out: str | None = None,
        merge: bool = False, backends=None, iters: int | None = None,
        warmup: int = 1):
    if smoke:
        shapes, backends = SMOKE_SHAPES, backends or ("rgb",)
        iters = iters or 1
    elif full:
        shapes = FULL_SHAPES
        iters = iters or 5
    else:
        shapes = QUICK_SHAPES
        iters = iters or 3
    rows = []
    table = tune(shapes, backends=backends, warmup=warmup, iters=iters,
                 on_result=_row_cb(rows))
    if smoke:
        _smoke_assertions(table, shapes)
    if out:
        path = Path(out)
        if merge and path.exists():
            table = TuningTable.load(path).merge(table)
        table.save(path)
        print(f"wrote {len(table)} entries for "
              f"{current_device_kind()!r} to {path}")
    return rows, table


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny space + subsystem contract assertions")
    ap.add_argument("--out", default=None,
                    help="write the resulting table JSON here")
    ap.add_argument("--merge", action="store_true",
                    help="merge into an existing --out (faster wins)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated subset (default: per device)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--warmup", type=int, default=1)
    args = ap.parse_args(argv)
    backends = tuple(args.backends.split(",")) if args.backends else None
    run(full=args.full, smoke=args.smoke, out=args.out,
        merge=args.merge, backends=backends, iters=args.iters,
        warmup=args.warmup)


if __name__ == "__main__":
    main()
