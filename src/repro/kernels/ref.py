"""Pure-jnp oracle for the Pallas RGB kernel.

Mirrors the kernel's exact interface (packed struct-of-arrays layout) but
computes with plain jnp on the unpacked representation, reusing the core
solver.  Every kernel test asserts allclose against this module.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lp import LPBatch
from repro.core.packed import PackedLPBatch, unpack
from repro.core.seidel import solve_rgb


def unpack_constraints(L, c, m_valid) -> LPBatch:
    """Raw packed arrays -> AoS batch (wrapper over core.packed.unpack)."""
    L = jnp.asarray(L)
    return unpack(PackedLPBatch(
        L=L, c=jnp.asarray(c),
        m_valid=jnp.asarray(m_valid).reshape(L.shape[0], 1)))


def solve_packed_ref(L, c, m_valid, *, M: float = 1.0e4):
    """Reference results for packed inputs: (x (B,2), feasible (B,) int32)."""
    sol = solve_rgb(unpack_constraints(L, c, m_valid), M=M)
    return sol.x, sol.feasible.astype(jnp.int32)
