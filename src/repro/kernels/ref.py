"""Pure-jnp oracle for the Pallas RGB kernel.

Mirrors the kernel's exact interface (packed struct-of-arrays layout) but
computes with plain jnp on the unpacked representation, reusing the core
solver.  Every kernel test asserts allclose against this module.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lp import LPBatch
from repro.core.seidel import solve_rgb


def unpack_constraints(L, c, m_valid) -> LPBatch:
    A = jnp.stack([L[:, 0, :], L[:, 1, :]], axis=-1)  # (B, m_pad, 2)
    b = L[:, 2, :]
    return LPBatch(A=A, b=b, c=c, m_valid=m_valid.reshape(-1).astype(jnp.int32))


def solve_packed_ref(L, c, m_valid, *, M: float = 1.0e4):
    """Reference results for packed inputs: (x (B,2), feasible (B,) int32)."""
    sol = solve_rgb(unpack_constraints(L, c, m_valid), M=M)
    return sol.x, sol.feasible.astype(jnp.int32)
