"""Compatibility layer for the Pallas RGB kernel — the *kernel backend*.

The packed struct-of-arrays layout the kernel consumes is now a
first-class type, :class:`repro.core.packed.PackedLPBatch`; the solver
core hands its ``L`` block to the kernel directly and a pre-packed
batch never round-trips back to AoS.  The public way to run the kernel
is ``repro.solver``::

    from repro.solver import SolverSpec
    sol = SolverSpec(backend="kernel", interpret=True).build().solve(batch)

This module keeps one historical entry point as a thin wrapper:
``pack_constraints`` over :func:`repro.core.packed.pack` (plus the
kernel's LANE-multiple validation) — the serving layer still uses it
to pack into an explicit shape bucket.
"""
from __future__ import annotations

from repro.core.lp import LPBatch
from repro.core.packed import pack, pad_packed
from repro.kernels.batch_lp import LANE


def pack_constraints(batch: LPBatch, m_pad: int | None = None):
    """LPBatch -> (L (B,4,m_pad), c (B,2), m_valid (B,1)) with unit-norm
    rows assumed (call lp.normalize_batch first).

    Thin wrapper over :func:`repro.core.packed.pack` that enforces the
    kernel's lane layout.  ``m_pad`` overrides the padding target: the
    serving layer passes its shape bucket here so every batch in a
    bucket packs to the *same* layout and hits the same compiled
    executable.  Prefer ``core.pack`` + ``core.pad_packed`` in new code
    — they return the :class:`~repro.core.packed.PackedLPBatch` the
    solver accepts directly."""
    m = batch.m
    if m_pad is None:
        m_pad = -(-m // LANE) * LANE
    if m_pad < m or m_pad % LANE:
        raise ValueError(f"m_pad={m_pad} must be a multiple of {LANE} "
                         f">= m={m}")
    pb = pad_packed(pack(batch), m_pad)
    return pb.L, pb.c, pb.m_valid
