"""Layout plumbing for the Pallas RGB kernel — the *kernel backend*.

This module is the implementation layer behind
``SolverSpec(backend="kernel")``: it converts an ``LPBatch`` to the
packed struct-of-arrays layout the kernel wants (constraint index on
the 128-lane minor axis) and pads the batch dimension to a tile
multiple with neutral problems.  The public way to run the kernel is
``repro.solver``::

    from repro.solver import SolverSpec
    sol = SolverSpec(backend="kernel", interpret=True).build().solve(batch)

``solve_batch_lp_kernel`` remains as a thin compatibility wrapper over
that path (note its historical ``normalize=False`` default — the
unified API defaults to True).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lp import LPBatch, LPSolution, PAD_B
from repro.kernels.batch_lp import LANE


def pack_constraints(batch: LPBatch, m_pad: int | None = None):
    """LPBatch -> (L (B,4,m_pad), c (B,2), m_valid (B,1)) with unit-norm
    rows assumed (call lp.normalize_batch first).

    ``m_pad`` overrides the lane padding target: the serving layer passes
    its shape bucket here so every batch in a bucket packs to the *same*
    layout and hits the same compiled executable, instead of recomputing a
    per-call minimal padding."""
    B, m = batch.batch, batch.m
    if m_pad is None:
        m_pad = -(-m // LANE) * LANE
    if m_pad < m or m_pad % LANE:
        raise ValueError(f"m_pad={m_pad} must be a multiple of {LANE} "
                         f">= m={m}")
    dt = batch.A.dtype
    ax = batch.A[..., 0]
    ay = batch.A[..., 1]
    bb = batch.b
    if m_pad != m:
        pad = ((0, 0), (0, m_pad - m))
        ax = jnp.pad(ax, pad)
        ay = jnp.pad(ay, pad)
        bb = jnp.pad(bb, pad, constant_values=PAD_B)
    zeros = jnp.zeros_like(ax)
    L = jnp.stack([ax, ay, bb, zeros], axis=1)  # (B, 4, m_pad)
    return L, batch.c.astype(dt), batch.m_valid.reshape(B, 1)


def _pad_batch_dim(L, c, mv, T):
    B = L.shape[0]
    Bp = -(-B // T) * T
    if Bp == B:
        return L, c, mv, B
    pad = Bp - B
    L = jnp.pad(L, ((0, pad), (0, 0), (0, 0)))
    # Neutral problems: c=(1,0), m_valid=0 -> solved at the box corner in
    # zero iterations; they never trigger a re-solve.
    c = jnp.concatenate(
        [c, jnp.broadcast_to(jnp.asarray([1.0, 0.0], c.dtype), (pad, 2))])
    mv = jnp.concatenate([mv, jnp.zeros((pad, 1), mv.dtype)])
    return L, c, mv, B


def solve_batch_lp_kernel(
    batch: LPBatch,
    *,
    M: float = 1.0e4,
    tile: int | None = None,
    chunk: int = 0,
    interpret: bool = False,
    normalize: bool = False,
) -> LPSolution:
    """Compatibility wrapper: solve an LPBatch with the Pallas kernel.

    Equivalent to ``SolverSpec(backend="kernel", ...)`` with this
    module's historical defaults (``normalize=False``,
    ``interpret=False``); prefer building that spec directly."""
    from repro.solver import SolverSpec, get_solver
    spec = SolverSpec(backend="kernel", tile=tile, chunk=chunk, M=M,
                      normalize=normalize, interpret=bool(interpret))
    return get_solver(spec).solve(batch)
