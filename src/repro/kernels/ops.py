"""Jit'd public wrappers around the Pallas RGB kernel.

Handles layout conversion (LPBatch -> packed struct-of-arrays with the
constraint index on the lane axis), padding (batch to a tile multiple with
neutral problems, constraints to a 128-lane multiple with neutral rows) and
unpacking of results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lp import LPBatch, LPSolution, PAD_B, normalize_batch
from repro.kernels.batch_lp import LANE, _pick_tile, rgb_pallas


def pack_constraints(batch: LPBatch, m_pad: int | None = None):
    """LPBatch -> (L (B,4,m_pad), c (B,2), m_valid (B,1)) with unit-norm
    rows assumed (call lp.normalize_batch first).

    ``m_pad`` overrides the lane padding target: the serving layer passes
    its shape bucket here so every batch in a bucket packs to the *same*
    layout and hits the same compiled executable, instead of recomputing a
    per-call minimal padding."""
    B, m = batch.batch, batch.m
    if m_pad is None:
        m_pad = -(-m // LANE) * LANE
    if m_pad < m or m_pad % LANE:
        raise ValueError(f"m_pad={m_pad} must be a multiple of {LANE} "
                         f">= m={m}")
    dt = batch.A.dtype
    ax = batch.A[..., 0]
    ay = batch.A[..., 1]
    bb = batch.b
    if m_pad != m:
        pad = ((0, 0), (0, m_pad - m))
        ax = jnp.pad(ax, pad)
        ay = jnp.pad(ay, pad)
        bb = jnp.pad(bb, pad, constant_values=PAD_B)
    zeros = jnp.zeros_like(ax)
    L = jnp.stack([ax, ay, bb, zeros], axis=1)  # (B, 4, m_pad)
    return L, batch.c.astype(dt), batch.m_valid.reshape(B, 1)


def _pad_batch_dim(L, c, mv, T):
    B = L.shape[0]
    Bp = -(-B // T) * T
    if Bp == B:
        return L, c, mv, B
    pad = Bp - B
    L = jnp.pad(L, ((0, pad), (0, 0), (0, 0)))
    # Neutral problems: c=(1,0), m_valid=0 -> solved at the box corner in
    # zero iterations; they never trigger a re-solve.
    c = jnp.concatenate(
        [c, jnp.broadcast_to(jnp.asarray([1.0, 0.0], c.dtype), (pad, 2))])
    mv = jnp.concatenate([mv, jnp.zeros((pad, 1), mv.dtype)])
    return L, c, mv, B


@functools.partial(jax.jit,
                   static_argnames=("M", "tile", "chunk", "interpret"))
def _solve_packed(L, c, mv, *, M, tile, chunk, interpret):
    L, c, mv, B = _pad_batch_dim(L, c, mv, tile)
    x, feas = rgb_pallas(L, c, mv, M=M, tile=tile, chunk=chunk,
                         interpret=interpret)
    return x[:B], feas[:B, 0]


def solve_batch_lp_kernel(
    batch: LPBatch,
    *,
    M: float = 1.0e4,
    tile: int | None = None,
    chunk: int = 0,
    interpret: bool = False,
    normalize: bool = False,
) -> LPSolution:
    """Solve an LPBatch with the Pallas kernel.  ``interpret=True`` executes
    the kernel body in Python on CPU (how this container validates it);
    on a TPU backend leave it False."""
    if normalize:
        batch = normalize_batch(batch)
    L, c, mv = pack_constraints(batch)
    T = tile or _pick_tile(L.shape[-1], L.shape[0])
    x, feas = _solve_packed(L, c, mv, M=M, tile=T, chunk=chunk,
                            interpret=interpret)
    return LPSolution(
        x=x,
        feasible=feas.astype(bool),
        objective=jnp.einsum("bd,bd->b", batch.c.astype(x.dtype), x),
    )
