"""Pallas TPU kernel for the RGB batch 2-D LP solver.

TPU-native realisation of the paper's cooperative-thread-array design
(DESIGN.md section 2):

* One **grid step** owns a tile of ``T`` problems (the thread-block
  analogue).  Constraints are stored struct-of-arrays, packed
  ``L[b, 0:3, h] = (a_x, a_y, b)`` with the constraint index ``h`` on the
  **128-lane minor axis** — the paper's "combining the information into one
  extended set of data ensures scattered reads use as much of each cache
  line as possible", except here every load is a full (8, 128) VMEM tile.
* The O(i) re-solve **work units** (one 1-D intersection per prior
  constraint) execute as dense vector ops along the lane axis; the paper's
  shared-memory ``atomicMin``/``atomicMax`` accumulation of u_left/u_right
  becomes a masked lane **min/max reduction** (TPUs have no atomics; a
  reduction tree is the idiomatic equivalent and is contention-free).
* A scalar-predicate ``lax.cond`` skips the whole re-solve when no problem
  in the tile is violated at step i — the block-level early exit that makes
  randomised constraint order pay (expected O(1) violations per problem).
* The iteration count is ``max(m_valid)`` over the tile (dynamic
  ``while_loop``), so a tile of small LPs finishes early even when another
  tile carries large LPs — the paper's "offloading work units of larger
  problems onto threads which are computing smaller problems" becomes
  "tiles only pay for their own largest problem".

All per-problem scalars are kept as (T, 1) so every intermediate is >= 2-D
(Mosaic requires >= 2-D iota / layouts).  The kernel is validated in
``interpret=True`` mode on CPU against ``kernels.ref`` and scipy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import oneD

DEFAULT_TILE = 128
LANE = 128


def _pick_tile(m_pad: int, batch: int | None = None,
               vmem_budget_bytes: int = 8 * 1024 * 1024,
               itemsize: int = 4) -> int:
    """Choose the batch tile so one grid step's VMEM working set fits the
    budget.  Per problem that is the packed constraint block (4 rows of
    m_pad lanes), the c input and x output (2 + 2 words) at the solve
    dtype's ``itemsize``, plus the int32 mv input and feas output
    (2 * 4 bytes) — so float64 solves get half-sized tiles instead of
    overshooting the budget 2x.  T stays a multiple of 8 (sublanes) and,
    when the batch size is known, is clamped to ceil(batch/8)*8 so a
    small batch is not padded all the way up to DEFAULT_TILE."""
    bytes_per_problem = (4 * m_pad + 4) * itemsize + 2 * 4
    t = vmem_budget_bytes // bytes_per_problem
    t = max(8, min(DEFAULT_TILE, (t // 8) * 8))
    if batch is not None:
        t = min(t, max(8, -(-batch // 8) * 8))
    return t


def _rgb_kernel(L_ref, c_ref, mv_ref, x_ref, feas_ref, *, M: float,
                chunk: int = 0):
    L = L_ref[...]            # (T, 4, m_pad) packed (a_x, a_y, b, 0)
    c = c_ref[...]            # (T, 2)
    mv = mv_ref[...]          # (T, 1) int32
    T, _, m_pad = L.shape
    dt = L.dtype

    ax = L[:, 0, :]           # (T, m_pad)
    ay = L[:, 1, :]
    bb = L[:, 2, :]

    cx = c[:, 0:1]            # (T, 1)
    cy = c[:, 1:2]
    cpx, cpy = -cy, cx        # perpendicular (tie-break) objective

    big = jnp.asarray(jnp.finfo(dt).max, dt)
    Mv = jnp.asarray(M, dt)
    h_iota = jax.lax.broadcasted_iota(jnp.int32, (T, m_pad), 1)

    def _sign_tb(v, tb):
        return jnp.where(jnp.abs(v) > oneD.EPS_TIE, jnp.sign(v),
                         jnp.where(jnp.abs(tb) > oneD.EPS_TIE,
                                   jnp.sign(tb), 1.0))

    x0 = jnp.concatenate(
        [_sign_tb(cx, cpx) * Mv, _sign_tb(cy, cpy) * Mv], axis=1)  # (T, 2)
    feas0 = jnp.ones((T, 1), jnp.bool_)
    max_mv = jnp.max(mv)

    def cond(carry):
        i, _, _ = carry
        return i < max_mv

    def body(carry):
        i, x, feas = carry
        a_ix = jax.lax.dynamic_slice_in_dim(ax, i, 1, axis=1)  # (T, 1)
        a_iy = jax.lax.dynamic_slice_in_dim(ay, i, 1, axis=1)
        b_i = jax.lax.dynamic_slice_in_dim(bb, i, 1, axis=1)
        lhs = a_ix * x[:, 0:1] + a_iy * x[:, 1:2]
        violated = feas & (i < mv) & (lhs > b_i + oneD.EPS_FEAS)  # (T, 1)

        def resolve(xf):
            x, feas = xf
            # Line frame: p0 = a_i * b_i (unit normals), u = perp(a_i).
            p0x, p0y = a_ix * b_i, a_iy * b_i
            ux, uy = -a_iy, a_ix

            def _bounds_block(axc, ayc, bbc, iota_c):
                """sigma bounds over one lane block (paper eqs. 3-4);
                the min/max is the atomicMin/atomicMax analogue."""
                denom = axc * ux + ayc * uy
                num = bbc - (axc * p0x + ayc * p0y)
                is_par = jnp.abs(denom) <= oneD.EPS_DENOM
                t = num / jnp.where(is_par, jnp.ones((), dt), denom)
                mask = iota_c < i
                hi = jnp.where(mask & (denom > oneD.EPS_DENOM), t, big)
                lo = jnp.where(mask & (denom < -oneD.EPS_DENOM), t, -big)
                bad = jnp.any(mask & is_par & (num < -oneD.EPS_FEAS),
                              axis=1, keepdims=True)
                return (jnp.max(lo, axis=1, keepdims=True),
                        jnp.min(hi, axis=1, keepdims=True), bad)

            if chunk:
                # chunked re-solve: only ceil(i/chunk) lane blocks of WUs
                # (work proportional to i, the true WU count)
                n_blocks = (i + chunk - 1) // chunk

                def blk(j, carry):
                    t_lo, t_hi, bad = carry
                    axc = jax.lax.dynamic_slice_in_dim(ax, j * chunk,
                                                       chunk, axis=1)
                    ayc = jax.lax.dynamic_slice_in_dim(ay, j * chunk,
                                                       chunk, axis=1)
                    bbc = jax.lax.dynamic_slice_in_dim(bb, j * chunk,
                                                       chunk, axis=1)
                    iota_c = j * chunk + jax.lax.broadcasted_iota(
                        jnp.int32, (T, chunk), 1)
                    lo_j, hi_j, bad_j = _bounds_block(axc, ayc, bbc, iota_c)
                    return (jnp.maximum(t_lo, lo_j),
                            jnp.minimum(t_hi, hi_j), bad | bad_j)

                t_lo, t_hi, par_bad = jax.lax.fori_loop(
                    0, n_blocks, blk,
                    (jnp.full((T, 1), -big, dt), jnp.full((T, 1), big, dt),
                     jnp.zeros((T, 1), jnp.bool_)))
            else:
                t_lo, t_hi, par_bad = _bounds_block(ax, ay, bb, h_iota)
            # --- The four box bounds, computed in closed form ---
            for bd, bn in (
                (ux, Mv - p0x), (-ux, Mv + p0x),
                (uy, Mv - p0y), (-uy, Mv + p0y),
            ):
                t_hi = jnp.minimum(
                    t_hi, jnp.where(bd > oneD.EPS_DENOM, bn / bd, big))
                t_lo = jnp.maximum(
                    t_lo, jnp.where(bd < -oneD.EPS_DENOM, bn / bd, -big))
                par_bad = par_bad | (
                    (jnp.abs(bd) <= oneD.EPS_DENOM) & (bn < -oneD.EPS_FEAS))
            feas_new = (t_lo <= t_hi + oneD.EPS_FEAS) & ~par_bad
            # Objective endpoint selection (tie -> perpendicular objective).
            cu = cx * ux + cy * uy
            cpu = cpx * ux + cpy * uy
            pick_hi = jnp.where(jnp.abs(cu) > oneD.EPS_TIE, cu > 0.0,
                                cpu > 0.0)
            tt = jnp.where(pick_hi, t_hi, t_lo)
            x_new = jnp.concatenate([p0x + tt * ux, p0y + tt * uy], axis=1)
            x = jnp.where(violated, x_new, x)
            feas = jnp.where(violated, feas & feas_new, feas)
            return x, feas

        x, feas = jax.lax.cond(jnp.any(violated), resolve, lambda xf: xf,
                               (x, feas))
        return i + 1, x, feas

    _, x, feas = jax.lax.while_loop(cond, body, (jnp.int32(0), x0, feas0))
    x_ref[...] = x
    feas_ref[...] = feas.astype(jnp.int32)


def rgb_pallas(
    L: jax.Array,        # (B, 4, m_pad) packed constraints, unit normals
    c: jax.Array,        # (B, 2)
    m_valid: jax.Array,  # (B, 1) int32
    *,
    M: float,
    tile: int | None = None,
    chunk: int = 0,      # 0 = dense re-solve; 128 = lane-width chunks
    interpret: bool = False,
):
    """Launch the RGB kernel.  B must be a multiple of the tile and m_pad a
    multiple of 128 (handled by kernels.ops)."""
    B, _, m_pad = L.shape
    T = tile or _pick_tile(m_pad, B, itemsize=L.dtype.itemsize)
    if B % T:
        raise ValueError(f"batch {B} not a multiple of tile {T}")
    if m_pad % LANE:
        raise ValueError(f"m_pad {m_pad} not a multiple of {LANE}")
    grid = (B // T,)
    flops_resolve = 12 * m_pad  # per problem per violation, approx
    if chunk and m_pad % chunk:
        raise ValueError(f"m_pad {m_pad} % chunk {chunk} != 0")
    kernel = functools.partial(_rgb_kernel, M=M, chunk=chunk)
    x, feas = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, 4, m_pad), lambda t: (t, 0, 0)),
            pl.BlockSpec((T, 2), lambda t: (t, 0)),
            pl.BlockSpec((T, 1), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((T, 2), lambda t: (t, 0)),
            pl.BlockSpec((T, 1), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 2), L.dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        cost_estimate=pl.CostEstimate(
            flops=B * flops_resolve * 2,  # ~2 ln m expected violations
            bytes_accessed=L.size * L.dtype.itemsize,
            transcendentals=0,
        ),
        interpret=interpret,
    )(L, c, m_valid)
    return x, feas
