"""The measured tuning loop: time candidates, record winners.

This is a proper measurement harness, not a wall-clock guess:

* the workload is a *representative packed batch* — the same
  :class:`~repro.core.packed.PackedLPBatch` layout the serving hot path
  feeds the solver, generated from the paper's random-feasible
  distribution at the target shape;
* every candidate is timed with ``warmup`` untimed calls first (pays
  the jit compile outside the measurement), then ``iters`` timed calls,
  each fenced with ``jax.block_until_ready`` so device work is actually
  included, and the **median** is kept (robust to scheduler noise);
* candidates are built as fully-explicit :class:`SolverSpec`\\ s (tile
  and chunk pinned), so timing a candidate never consults the tuning
  table — no feedback loop between measuring and resolving.

:func:`tune` drives the space over a grid of shapes and folds the
per-backend winners into a :class:`~repro.tune.table.TuningTable`; the
offline entry point is ``benchmarks/tune_cli.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Sequence, Tuple

import jax

from repro.core.lp import random_feasible_lp
from repro.core.packed import PackedLPBatch, pack
from repro.solver import SolverSpec
from repro.tune.space import Candidate, candidate_space
from repro.tune.table import (BATCH_BUCKET_BASE, M_BUCKET_BASE, TableEntry,
                              TableKey, TuningTable, bucket_pow2,
                              current_device_kind)

DEFAULT_WARMUP = 1
DEFAULT_ITERS = 5


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """One timed candidate at one shape, with the measurement spread
    (``iqr_seconds`` over ``k`` repetitions) kept alongside the median
    so table merges can tell improvement from noise."""

    candidate: Candidate
    m_pad: int
    batch: int
    dtype: str
    device_kind: str
    seconds: float            # median wall-clock per solve
    iqr_seconds: float = 0.0  # interquartile range of the samples
    k: int = 1                # timed repetitions

    @property
    def us_per_lp(self) -> float:
        return self.seconds / self.batch * 1e6

    @property
    def us_iqr(self) -> float:
        return self.iqr_seconds / self.batch * 1e6


def measure_stats(fn, *args, warmup: int = DEFAULT_WARMUP,
                  iters: int = DEFAULT_ITERS
                  ) -> Tuple[float, float, int]:
    """``(median, iqr, k)`` wall-clock seconds of ``fn(*args)``,
    device-fenced.  The IQR (75th - 25th percentile of the sorted
    samples, by index — exact quartile interpolation would be false
    precision at these k) is the noise band table merges honour."""
    if iters < 1:
        raise ValueError(f"iters={iters} < 1")
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    n = len(ts)
    median = ts[n // 2]
    iqr = ts[(3 * n) // 4] - ts[n // 4] if n > 1 else 0.0
    return median, iqr, n


def measure(fn, *args, warmup: int = DEFAULT_WARMUP,
            iters: int = DEFAULT_ITERS) -> float:
    """Median wall-clock seconds of ``fn(*args)``, device-fenced."""
    return measure_stats(fn, *args, warmup=warmup, iters=iters)[0]


def representative_batch(m_pad: int, batch: int, *,
                         dtype: str = "float32",
                         seed: int = 0) -> PackedLPBatch:
    """A packed random-feasible batch at the target shape — the layout
    and distribution the serving hot path actually runs."""
    lp = random_feasible_lp(jax.random.key(seed ^ (m_pad * 7919 + batch)),
                            batch, m_pad)
    pb = pack(lp)
    if dtype != "float32":
        pb = PackedLPBatch(L=pb.L.astype(dtype), c=pb.c.astype(dtype),
                           m_valid=pb.m_valid)
    return pb


def candidate_spec(cand: Candidate, *, dtype: str = "float32",
                   interpret: Optional[bool] = None) -> SolverSpec:
    """The fully-explicit spec for one candidate (tile and chunk pinned,
    so resolution never re-enters the tuning table).  A pdhg
    candidate's slots map back to its iteration schedule."""
    if cand.backend == "pdhg":
        return SolverSpec(backend="pdhg", iter_block=cand.tile,
                          restart_period=cand.chunk, dtype=dtype)
    return SolverSpec(backend=cand.backend, tile=cand.tile,
                      chunk=cand.chunk, dtype=dtype, interpret=interpret)


def time_candidate(cand: Candidate, pb: PackedLPBatch, *,
                   dtype: str = "float32",
                   interpret: Optional[bool] = None,
                   warmup: int = DEFAULT_WARMUP,
                   iters: int = DEFAULT_ITERS) -> float:
    """Median seconds for one candidate over one packed batch."""
    return time_candidate_stats(cand, pb, dtype=dtype,
                                interpret=interpret, warmup=warmup,
                                iters=iters)[0]


def time_candidate_stats(cand: Candidate, pb: PackedLPBatch, *,
                         dtype: str = "float32",
                         interpret: Optional[bool] = None,
                         warmup: int = DEFAULT_WARMUP,
                         iters: int = DEFAULT_ITERS
                         ) -> Tuple[float, float, int]:
    """``(median, iqr, k)`` seconds for one candidate over one packed
    batch."""
    solver = candidate_spec(cand, dtype=dtype,
                            interpret=interpret).build()
    return measure_stats(solver.solve, pb, warmup=warmup, iters=iters)


def tune_shape(
    m_pad: int,
    batch: int,
    *,
    dtype: str = "float32",
    backends: Optional[Sequence[str]] = None,
    device_kind: Optional[str] = None,
    interpret: Optional[bool] = None,
    warmup: int = DEFAULT_WARMUP,
    iters: int = DEFAULT_ITERS,
    seed: int = 0,
) -> List[TuneResult]:
    """Time every valid candidate at one shape; sorted fastest-first."""
    kind = device_kind if device_kind is not None else current_device_kind()
    pb = representative_batch(m_pad, batch, dtype=dtype, seed=seed)
    results = []
    for cand in candidate_space(m_pad, batch, dtype=dtype,
                                device_kind=kind, backends=backends):
        seconds, iqr, k = time_candidate_stats(
            cand, pb, dtype=dtype, interpret=interpret, warmup=warmup,
            iters=iters)
        results.append(TuneResult(candidate=cand, m_pad=m_pad,
                                  batch=batch, dtype=dtype,
                                  device_kind=kind, seconds=seconds,
                                  iqr_seconds=iqr, k=k))
    results.sort(key=lambda r: r.seconds)
    return results


def results_to_entries(results: Iterable[TuneResult]) -> List[TableEntry]:
    """Per-backend winners of one shape's results as table entries."""
    best = {}
    for r in results:
        cur = best.get(r.candidate.backend)
        if cur is None or r.seconds < cur.seconds:
            best[r.candidate.backend] = r
    entries = []
    for r in best.values():
        key = TableKey(
            device_kind=r.device_kind, backend=r.candidate.backend,
            dtype=r.dtype,
            m_bucket=bucket_pow2(r.m_pad, M_BUCKET_BASE),
            batch_bucket=bucket_pow2(r.batch, BATCH_BUCKET_BASE))
        entries.append(TableEntry(key=key, tile=r.candidate.tile,
                                  chunk=r.candidate.chunk,
                                  us_per_lp=r.us_per_lp,
                                  us_iqr=r.us_iqr, k=r.k))
    return entries


def tune(
    shapes: Sequence[Tuple[int, int]],
    *,
    dtype: str = "float32",
    backends: Optional[Sequence[str]] = None,
    device_kind: Optional[str] = None,
    interpret: Optional[bool] = None,
    warmup: int = DEFAULT_WARMUP,
    iters: int = DEFAULT_ITERS,
    table: Optional[TuningTable] = None,
    on_result=None,
) -> TuningTable:
    """Tune a grid of ``(m_pad, batch)`` shapes into a table.

    ``table`` (if given) is updated in place via the faster-wins merge;
    ``on_result`` is an optional callback fired with every
    :class:`TuneResult` as it lands (the CLI uses it to stream JSON
    rows)."""
    if table is None:
        table = TuningTable()
    for m_pad, batch in shapes:
        results = tune_shape(m_pad, batch, dtype=dtype, backends=backends,
                             device_kind=device_kind, interpret=interpret,
                             warmup=warmup, iters=iters)
        if on_result is not None:
            for r in results:
                on_result(r)
        table.merge(TuningTable(results_to_entries(results)))
    return table
