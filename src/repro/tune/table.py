"""Persisted per-device timing tables — the measurement artifact that
replaces launch-geometry guessing.

A :class:`TuningTable` maps a :class:`TableKey` — ``(device_kind,
backend, dtype, m_bucket, batch_bucket)`` — to the fastest measured
``(tile, chunk)`` for that shape class, together with the measurement
statistics ``(us_per_lp median, us_iqr, k repetitions)`` so merges can
tell a real improvement from timing noise.  Shape dimensions are
bucketed on the same geometric ladders the serving layer uses (double
from a small base), so one entry covers every shape that lands in its
bucket and the table stays a few dozen rows per device.

Tables serialise to versioned JSON (:meth:`TuningTable.save` /
:meth:`TuningTable.load`), merge monotonically with a noise dead zone
(a new entry wins only when faster by more than the larger of the two
IQRs, so re-running the tuner can only genuinely improve the table),
and ship with a bundled default (``default_table.json``, CPU entries
measured by ``benchmarks/tune_cli.py`` in the reference container, TPU
entries seeded from the VMEM heuristic until the CLI runs on real
hardware).  Rows written before the stats slice load unchanged —
``us_iqr``/``k`` default to ``0.0``/``1`` (no spread recorded).

The process-wide *active table* is what
:meth:`repro.solver.SolverSpec.resolve_for_shape` consults.  It is the
bundled default, optionally overlaid with the file named by the
``REPRO_TUNE_TABLE`` environment variable; tests and callers can pin a
specific table with :func:`set_active_table` or the :func:`use_table`
context manager.  A lookup miss is never an error — resolution falls
back to the static heuristics.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
from pathlib import Path
from typing import Dict, Iterable, List, Optional

SCHEMA_VERSION = 1

# Bucketing bases: m doubles from 8 (the dense serving ladder; kernel
# shapes land on 128+ rungs of the same ladder), batch doubles from 8.
M_BUCKET_BASE = 8
BATCH_BUCKET_BASE = 8

# Environment override: a JSON table merged over the bundled default.
ENV_TABLE_VAR = "REPRO_TUNE_TABLE"

_DEFAULT_TABLE_PATH = Path(__file__).with_name("default_table.json")


def bucket_pow2(x: int, base: int) -> int:
    """Round ``x`` up the geometric ladder {base, 2*base, 4*base, ...}."""
    if x < 1:
        raise ValueError(f"bucket_pow2({x}): need x >= 1")
    b = base
    while b < x:
        b *= 2
    return b


def normalize_device_kind(kind: str) -> str:
    """Canonical table key form of a jax ``device_kind`` string
    (lower-case, spaces/underscores collapsed to dashes):
    ``"TPU v4" -> "tpu-v4"``."""
    return "-".join(str(kind).lower().replace("_", " ").split())


def device_platform(kind: str) -> str:
    """The platform family of a (normalized) device kind — the fallback
    lookup key that lets one "cpu"/"tpu" row cover every model of the
    family."""
    k = normalize_device_kind(kind)
    for fam in ("tpu", "gpu", "cpu"):
        if k.startswith(fam):
            return fam
    # jax CPU devices report device_kind "cpu"; anything unrecognised
    # keys on its own normalized name only.
    return k


def current_device_kind() -> str:
    """Normalized device kind of the first visible jax device."""
    import jax  # deferred so table manipulation works without a backend
    return normalize_device_kind(jax.devices()[0].device_kind)


@dataclasses.dataclass(frozen=True)
class TableKey:
    """Everything a timing record is conditioned on."""

    device_kind: str   # normalized (see normalize_device_kind)
    backend: str       # "naive" | "rgb" | "kernel" | "pdhg"
    dtype: str         # "float32" | "float64"
    m_bucket: int      # bucket_pow2(m_pad, M_BUCKET_BASE)
    batch_bucket: int  # bucket_pow2(batch, BATCH_BUCKET_BASE); 0 = any

    def __post_init__(self):
        object.__setattr__(self, "device_kind",
                           normalize_device_kind(self.device_kind))


@dataclasses.dataclass(frozen=True)
class TableEntry:
    """One measured (or seeded) winning configuration.

    For ``backend="pdhg"`` rows the ``(tile, chunk)`` slots carry the
    iteration schedule ``(iter_block, restart_period)`` — same shape,
    same validation (``iter_block >= 1``, ``restart_period >= 0``), no
    schema bump; ``SolverSpec.resolve_for_shape`` reads them back into
    the pdhg knobs."""

    key: TableKey
    tile: int
    chunk: int
    us_per_lp: float          # measured median microseconds per LP
    source: str = "measured"  # "measured" | "heuristic-seed"
    us_iqr: float = 0.0       # interquartile range of the µs/LP samples
    k: int = 1                # timing repetitions behind the median

    def __post_init__(self):
        if self.tile < 1:
            raise ValueError(f"tile={self.tile} < 1")
        if self.chunk < 0:
            raise ValueError(f"chunk={self.chunk} < 0")
        if not self.us_per_lp >= 0.0:
            raise ValueError(f"us_per_lp={self.us_per_lp} must be >= 0")
        if not self.us_iqr >= 0.0:
            raise ValueError(f"us_iqr={self.us_iqr} must be >= 0")
        if self.k < 1:
            raise ValueError(f"k={self.k} < 1")

    @property
    def noise_band_us(self) -> float:
        """The spread below which two medians of this entry are
        statistically indistinguishable (its IQR; 0 for single-shot
        or seeded entries — they carry no spread information)."""
        return self.us_iqr


class TuningTable:
    """An in-memory set of timing records with JSON persistence.

    ``put`` overwrites; ``merge`` keeps the faster record per key, so
    ``table.merge(rerun)`` is monotone — stale slow entries can only be
    replaced by better measurements.
    """

    def __init__(self, entries: Iterable[TableEntry] = ()):
        self._entries: Dict[TableKey, TableEntry] = {}
        for e in entries:
            self.put(e)

    def __len__(self) -> int:
        return len(self._entries)

    def __eq__(self, other) -> bool:
        return (isinstance(other, TuningTable)
                and self._entries == other._entries)

    def entries(self) -> List[TableEntry]:
        return sorted(
            self._entries.values(),
            key=lambda e: dataclasses.astuple(e.key))

    def put(self, entry: TableEntry) -> None:
        self._entries[entry.key] = entry

    def get(self, key: TableKey) -> Optional[TableEntry]:
        return self._entries.get(key)

    def merge(self, other: "TuningTable") -> "TuningTable":
        """Fold ``other`` into this table in place; returns self for
        chaining.

        A new entry wins only when it is faster *beyond the noise
        band* — the larger of the two entries' recorded IQRs — so
        re-running the tuner on a noisy machine cannot churn the table
        with statistically meaningless "improvements" (merge stays
        monotone in measured speed, now with a dead zone).  Two
        exceptions keep the table honest: a measured entry always
        replaces a heuristic seed (seeds carry sentinel timings, not
        measurements), and a seed never replaces a measurement."""
        for key, entry in other._entries.items():
            mine = self._entries.get(key)
            if mine is None:
                self._entries[key] = entry
                continue
            if entry.source == "heuristic-seed":
                if mine.source == "heuristic-seed" \
                        and entry.us_per_lp < mine.us_per_lp:
                    self._entries[key] = entry
                continue
            if mine.source == "heuristic-seed":
                self._entries[key] = entry
                continue
            band = max(entry.noise_band_us, mine.noise_band_us)
            if entry.us_per_lp < mine.us_per_lp - band:
                self._entries[key] = entry
        return self

    # -- lookup ----------------------------------------------------------

    def lookup(self, *, backend: str, dtype: str, m: int,
               batch: Optional[int] = None,
               device_kind: Optional[str] = None) -> Optional[TableEntry]:
        """Best recorded config for a shape class, or None (a miss is
        the caller's cue to fall back to heuristics, never an error).

        Tries the exact device kind first, then its platform family
        ("tpu-v4" -> "tpu"); within a device, the exact batch bucket
        first, then the batch-wildcard rung (batch_bucket=0).
        """
        if device_kind is None:
            device_kind = current_device_kind()
        device_kind = normalize_device_kind(device_kind)
        mb = bucket_pow2(m, M_BUCKET_BASE)
        bbs = ([bucket_pow2(batch, BATCH_BUCKET_BASE)]
               if batch is not None else [])
        bbs.append(0)
        kinds = [device_kind]
        fam = device_platform(device_kind)
        if fam != device_kind:
            kinds.append(fam)
        for kind in kinds:
            for bb in bbs:
                hit = self._entries.get(TableKey(
                    device_kind=kind, backend=backend, dtype=dtype,
                    m_bucket=mb, batch_bucket=bb))
                if hit is not None:
                    return hit
        return None

    def lookup_best_backend(self, *, dtype: str, m: int,
                            batch: Optional[int] = None,
                            device_kind: Optional[str] = None,
                            backends: Iterable[str] = ("naive", "rgb",
                                                       "kernel", "pdhg"),
                            ) -> Optional[TableEntry]:
        """Fastest recorded entry across backends for a shape class —
        what ``backend="auto"`` resolution uses when measurements
        exist."""
        hits = [e for e in (self.lookup(backend=b, dtype=dtype, m=m,
                                        batch=batch,
                                        device_kind=device_kind)
                            for b in backends) if e is not None]
        if not hits:
            return None
        return min(hits, key=lambda e: e.us_per_lp)

    # -- persistence -----------------------------------------------------

    def to_json(self) -> dict:
        return {
            "version": SCHEMA_VERSION,
            "entries": [
                {**dataclasses.asdict(e.key), "tile": e.tile,
                 "chunk": e.chunk, "us_per_lp": e.us_per_lp,
                 "source": e.source, "us_iqr": e.us_iqr, "k": e.k}
                for e in self.entries()
            ],
        }

    @classmethod
    def from_json(cls, doc: dict) -> "TuningTable":
        version = doc.get("version")
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"tuning table schema version {version!r} != "
                f"{SCHEMA_VERSION}; regenerate with benchmarks/tune_cli")
        entries = []
        for row in doc.get("entries", []):
            row = dict(row)
            key = TableKey(
                device_kind=row.pop("device_kind"),
                backend=row.pop("backend"), dtype=row.pop("dtype"),
                m_bucket=int(row.pop("m_bucket")),
                batch_bucket=int(row.pop("batch_bucket")))
            # us_iqr/k default for rows written before the stats slice
            # (same version: old tables load, their entries just carry
            # no spread and merge with a zero noise band).
            entries.append(TableEntry(
                key=key, tile=int(row["tile"]), chunk=int(row["chunk"]),
                us_per_lp=float(row["us_per_lp"]),
                source=str(row.get("source", "measured")),
                us_iqr=float(row.get("us_iqr", 0.0)),
                k=int(row.get("k", 1))))
        return cls(entries)

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2,
                                   sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "TuningTable":
        return cls.from_json(json.loads(Path(path).read_text()))


# -- the process-wide active table ----------------------------------------

_lock = threading.Lock()
_active: Optional[TuningTable] = None


def default_table() -> TuningTable:
    """The bundled table (fresh copy; missing/corrupt file -> empty)."""
    try:
        return TuningTable.load(_DEFAULT_TABLE_PATH)
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return TuningTable()


def _initial_table() -> TuningTable:
    table = default_table()
    env_path = os.environ.get(ENV_TABLE_VAR)
    if env_path:
        try:
            table.merge(TuningTable.load(env_path))
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            pass  # a broken override must never take the solver down
    return table


def active_table() -> TuningTable:
    """The table solver resolution consults (lazily initialised to the
    bundled default + ``REPRO_TUNE_TABLE`` overlay)."""
    global _active
    with _lock:
        if _active is None:
            _active = _initial_table()
        return _active


def set_active_table(table: Optional[TuningTable]) -> None:
    """Pin the process-wide table (``None`` resets to lazy default).

    Note: solvers jit-cache per input shape, and the table is consulted
    at trace time — entries changed *after* a shape has been traced do
    not retrigger compilation for that shape.
    """
    global _active
    with _lock:
        _active = table


@contextlib.contextmanager
def use_table(table: Optional[TuningTable]):
    """Scoped :func:`set_active_table` (restores the previous table)."""
    global _active
    with _lock:
        prev = _active
        _active = table
    try:
        yield table
    finally:
        with _lock:
            _active = prev


def lookup(*, backend: str, dtype: str, m: int,
           batch: Optional[int] = None,
           device_kind: Optional[str] = None) -> Optional[TableEntry]:
    """Module-level convenience over ``active_table().lookup``."""
    return active_table().lookup(backend=backend, dtype=dtype, m=m,
                                 batch=batch, device_kind=device_kind)
