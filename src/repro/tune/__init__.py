"""repro.tune — measured autotuning for solver launch geometry.

The paper's speedups hinge on launch geometry matched to the hardware;
this subsystem replaces the static tile/chunk heuristics with a
*measured* per-device timing table:

* :mod:`~repro.tune.space` enumerates the valid ``(backend, tile,
  chunk)`` candidates for a shape class;
* :mod:`~repro.tune.runner` times them over representative packed
  batches (warmup, ``block_until_ready``, median-of-k);
* :mod:`~repro.tune.table` persists the winners in a versioned JSON
  :class:`TuningTable` keyed by ``(device_kind, backend, dtype,
  m bucket, batch bucket)``, with load/merge/save and a bundled
  default for CPU + TPU.

Resolution precedence is *explicit > table > heuristic*:
:meth:`repro.solver.SolverSpec.resolve_for_shape` consults the active
table only for fields the user left unset, and a table miss silently
falls back to the static heuristics — tuning can change performance,
never availability.

Regenerate tables offline with ``python -m benchmarks.tune_cli``; pin a
table per process with :func:`set_active_table`/:func:`use_table` or
the ``REPRO_TUNE_TABLE`` environment variable.
"""
from repro.tune.runner import (TuneResult, measure, measure_stats,
                               representative_batch,
                               results_to_entries, time_candidate,
                               time_candidate_stats, tune,
                               tune_shape)
from repro.tune.space import (Candidate, candidate_space,
                              default_backends)
from repro.tune.table import (SCHEMA_VERSION, TableEntry, TableKey,
                              TuningTable, active_table, bucket_pow2,
                              current_device_kind, default_table,
                              device_platform, lookup,
                              normalize_device_kind, set_active_table,
                              use_table)

__all__ = [
    "Candidate", "SCHEMA_VERSION", "TableEntry", "TableKey",
    "TuneResult", "TuningTable", "active_table", "bucket_pow2",
    "candidate_space", "current_device_kind", "default_backends",
    "default_table", "device_platform", "lookup", "measure",
    "measure_stats", "normalize_device_kind", "representative_batch",
    "results_to_entries", "set_active_table", "time_candidate",
    "time_candidate_stats", "tune", "tune_shape", "use_table",
]
