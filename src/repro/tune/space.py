"""Candidate launch-geometry enumeration.

A :class:`Candidate` is one ``(backend, tile, chunk)`` configuration
the tuner may time.  :func:`candidate_space` enumerates exactly the
configurations that are *valid* for a given ``(m_pad, batch, dtype,
device kind)`` — the constraints mirror the execution layers:

* ``naive`` has no launch geometry (vmap over problems): a single
  candidate, recorded with the serving-default tile so the entry can
  still drive the scheduler's batch ladder.
* ``rgb`` tiles are powers of two (8..256), clamped so a tile never
  exceeds the (sublane-rounded) batch; chunks are 0 (dense re-solve)
  or lane-sized blocks strictly smaller than the padded constraint
  count (a chunk >= m_pad degenerates to the dense variant).
* ``kernel`` tiles are sublane multiples capped at the Pallas
  ``DEFAULT_TILE`` and filtered by the same VMEM working-set budget
  ``_pick_tile`` uses (a candidate that cannot fit VMEM is not worth
  timing); chunks must divide the LANE-rounded ``m_pad`` exactly
  (``rgb_pallas`` rejects anything else).
* ``pdhg`` has no launch geometry — its knobs are the iteration
  schedule.  A pdhg candidate reinterprets the ``(tile, chunk)`` slots
  as ``(iter_block, restart_period)`` (the same reinterpretation
  :class:`~repro.tune.table.TableEntry` records and
  ``SolverSpec.resolve_for_shape`` reads back), so the tuner, table
  and resolution stay schema-compatible across backends.

Everything returned here is safe to *run*; which candidate is fastest
is the runner's job to measure, never this module's to guess.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.kernels.batch_lp import DEFAULT_TILE, LANE, _pick_tile
from repro.solver.spec import DTYPES, RGB_DEFAULT_TILE, jnp_itemsize
from repro.tune.table import current_device_kind, device_platform

RGB_TILES = (8, 16, 32, 64, 128, 256)
RGB_CHUNKS = (0, 64, 128)
KERNEL_TILES = (8, 16, 32, 64, 128)
KERNEL_CHUNKS = (0, 128, 256)
# pdhg iteration schedule, riding in the (tile, chunk) slots.
PDHG_ITER_BLOCKS = (32, 64, 128)
PDHG_RESTART_PERIODS = (0, 512, 2048)

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # matches _pick_tile's budget


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One tunable configuration (tile/chunk are concrete, never None).

    For ``backend="pdhg"`` the slots carry ``(iter_block,
    restart_period)`` instead of launch geometry."""

    backend: str
    tile: int
    chunk: int

    def label(self) -> str:
        if self.backend == "pdhg":
            return f"pdhg/ib{self.tile}/rp{self.chunk}"
        return f"{self.backend}/t{self.tile}/c{self.chunk}"


def default_backends(device_kind: Optional[str] = None) -> tuple:
    """Backends worth timing on a device family: the Pallas kernel only
    runs compiled on TPU (interpret mode measures the emulator, not the
    hardware), the dense pair runs everywhere, and pdhg is the
    large-m first-order contender on every platform."""
    kind = device_kind if device_kind is not None else current_device_kind()
    if device_platform(kind) == "tpu":
        return ("rgb", "kernel", "pdhg")
    return ("naive", "rgb", "pdhg")


def candidate_space(
    m_pad: int,
    batch: int,
    *,
    dtype: str = "float32",
    device_kind: Optional[str] = None,
    backends: Optional[Sequence[str]] = None,
) -> List[Candidate]:
    """All valid candidates for one shape class, deterministic order."""
    if m_pad < 1 or batch < 1:
        raise ValueError(f"need m_pad >= 1 and batch >= 1, got "
                         f"({m_pad}, {batch})")
    if dtype not in DTYPES:
        raise ValueError(f"dtype={dtype!r}; expected one of {DTYPES}")
    itemsize = jnp_itemsize(dtype)
    if backends is None:
        backends = default_backends(device_kind)
    batch_cap = max(8, -(-batch // 8) * 8)  # sublane-rounded batch
    out: List[Candidate] = []
    for backend in backends:
        if backend == "naive":
            out.append(Candidate("naive", RGB_DEFAULT_TILE, 0))
        elif backend == "rgb":
            for tile in RGB_TILES:
                if tile > batch_cap and tile != RGB_TILES[0]:
                    continue  # keep one rung even for tiny batches
                for chunk in RGB_CHUNKS:
                    if chunk and chunk >= m_pad:
                        continue
                    out.append(Candidate("rgb", tile, chunk))
        elif backend == "kernel":
            m_lane = -(-m_pad // LANE) * LANE
            # largest VMEM-feasible tile for this shape/dtype
            t_max = _pick_tile(m_lane, None,
                               vmem_budget_bytes=VMEM_BUDGET_BYTES,
                               itemsize=itemsize)
            for tile in KERNEL_TILES:
                if tile > min(t_max, DEFAULT_TILE, batch_cap) \
                        and tile != KERNEL_TILES[0]:
                    continue
                for chunk in KERNEL_CHUNKS:
                    if chunk and (chunk >= m_lane or m_lane % chunk):
                        continue
                    out.append(Candidate("kernel", tile, chunk))
        elif backend == "pdhg":
            for iter_block in PDHG_ITER_BLOCKS:
                for period in PDHG_RESTART_PERIODS:
                    if period and period < iter_block:
                        continue  # a period under one block never fires
                    out.append(Candidate("pdhg", iter_block, period))
        else:
            raise ValueError(f"unknown backend {backend!r}")
    return out
