"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: 35L d7168
56H (GQA kv=8) MoE 128 experts top-2 with a parallel dense-FFN residual
(d_ff=4864).  FSDP is mandatory: 480B bf16 params only fit when sharded
over all 512 chips."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=4864,
    vocab=32000,
    n_experts=128,
    top_k=2,
    moe_dense_ff=4864,
    fsdp=True,
)
