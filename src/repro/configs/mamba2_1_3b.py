"""Mamba2-1.3B [arXiv:2405.21060]: 48L d2048 attention-free SSD,
ssm_state=128, expand=2 (d_inner 4096, 64 heads of 64), vocab 50280."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
)
