"""Architecture registry, input shapes, ShapeDtypeStruct builders and
reduced smoke configs for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen15
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.paligemma_3b import CONFIG as _paligemma

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _olmoe, _arctic, _granite, _qwen2, _internlm2, _qwen15,
        _whisper, _mamba2, _zamba2, _paligemma,
    )
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k needs sub-quadratic sequence mixing: run for SSM/hybrid,
    skip for pure full-attention archs (DESIGN.md section 4)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def input_specs(cfg: ModelConfig, shape: InputShape,
                batch: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
    shardable, no device allocation."""
    B = batch if batch is not None else shape.batch
    S = shape.seq
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    d = cfg.d_model

    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32),
        }

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        s_text = S - cfg.n_prefix
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_prefix, d), act)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    elif cfg.family == "encdec":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, d), act)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny embedding tables."""
    common = dict(n_layers=2, d_model=64, vocab=257, fsdp=False)
    if cfg.family in ("dense", "vlm"):
        kw = dict(common, n_heads=4, n_kv=min(max(cfg.n_kv, 1), 2),
                  d_ff=96, head_dim=16 if cfg.head_dim else 0)
        if cfg.family == "vlm":
            kw["n_prefix"] = 8
        return dataclasses.replace(cfg, **kw)
    if cfg.family == "moe":
        return dataclasses.replace(
            cfg, **common, n_heads=4, n_kv=2, d_ff=48, n_experts=8,
            top_k=2, moe_dense_ff=32 if cfg.moe_dense_ff else 0)
    if cfg.family == "encdec":
        return dataclasses.replace(
            cfg, **common, enc_layers=2, enc_seq=16, n_heads=4, n_kv=4,
            d_ff=96)
    if cfg.family == "ssm":
        return dataclasses.replace(
            cfg, **common, ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, n_layers=4, d_model=64, vocab=257, fsdp=False,
            n_heads=4, n_kv=4, d_ff=96, ssm_state=16, ssm_head_dim=16,
            ssm_chunk=16, hybrid_period=2)
    raise ValueError(cfg.family)


SMOKE_SHAPE = InputShape("smoke", "train", 32, 2)
