"""The paper's own workload: batched 2-D LPs.

Problem-size grid mirroring the paper's experiments (section 4): LP sizes
(constraints per problem) sweep 2^3..2^13 and batch amounts sweep
2^7..2^17 (their figures 3a-3c use batches {128, 2048, 16384}; figure 4
sweeps batch at sizes {64, 8192})."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LPWorkload:
    name: str
    batch: int
    m: int  # constraints per LP
    dtype: str = "float32"


FIG3_BATCHES = (128, 2048, 16384)
FIG3_SIZES = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192)
FIG4_SIZES = (64, 8192)
FIG4_BATCHES = (128, 512, 2048, 8192, 32768, 131072)

# production-scale batch for the multi-pod dry-run: one LP per "agent"
PRODUCTION = LPWorkload(name="lp-production", batch=1 << 20, m=256)
