"""Whisper-base [arXiv:2212.04356]: enc-dec, 6+6L d512 8H d_ff=2048
vocab 51865.  The conv audio frontend is a STUB per the brief:
input_specs() provides precomputed frame embeddings (B, enc_seq, d)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,       # decoder layers
    enc_layers=6,
    enc_seq=1504,     # whisper's 1500 frames, padded to a flash-chunk mult
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    qkv_bias=True,
)
