"""Qwen2-0.5B [arXiv:2407.10671; hf]: 24L d896 14H (GQA kv=2) d_ff=4864
vocab 151936, QKV bias.  14 heads pad to 16 zero-heads for TP-16
(DESIGN.md: zero wq/wo rows keep the function exact)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
)
