"""PaliGemma-3B [arXiv:2407.07726; hf]: gemma backbone 18L d2048 8H
(GQA kv=1, head_dim 256) d_ff=16384 vocab 257216; SigLIP vision frontend
is a STUB (input_specs() provides 256 precomputed patch embeddings) with
prefix-LM masking over the patch prefix."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    gelu_glu=True,
    embed_scale=True,
    n_prefix=256,
)
