"""Granite-8B code [arXiv:2405.04324; hf]: llama-arch 36L d4096 32H
(GQA kv=8) d_ff=14336 vocab 49152."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=49152,
    fsdp=True,
)
