"""Zamba2-2.7B [arXiv:2411.15242; hf]: 54 Mamba2 layers d2560
(ssm_state=64, d_inner 5120, 80 heads of 64) + one SHARED attention+MLP
block (32H GQA kv=32, d_ff=10240) applied every 6 layers."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_period=6,
)
