"""Open-loop serving benchmark for the LP scheduler.

Synthetic traffic is drawn from deterministic numpy generators (seeded,
pipeline-style): constraint counts are mixed across a log2 ladder and
each request is feasible, infeasible or degenerate (all constraints
tight at one point) per a fixed mix.  Requests are submitted open-loop
at a target rate; the report covers throughput, p50/p99 latency,
padding waste, executable-cache hit rate and the pipeline gauges
(in-flight depth, overlapped dispatches, device-idle estimate).

``--open-loop`` removes the rate throttle entirely (saturating burst):
submission always outruns the device, so with pipelining enabled the
scheduler demonstrably keeps >= 2 flushes in flight while the host
assembles the next one — ``--assert-overlap`` turns that claim into a
hard check (used by CI).  ``--no-pipeline`` runs the same traffic
through the stop-and-go loop for an A/B of the overlap win.

``--sharding {mesh,pmap}`` picks the flush executable path (mesh is the
default: shard_map launches with uneven per-device shards and
cross-bucket fusing; pmap is the legacy escape hatch), and
``--assert-fused`` turns "underfull buckets actually fused into shared
launches" into a hard check (used by CI).

``--trace-out trace.json`` runs the traffic under a ``repro.obs``
tracer and writes the span ring as Chrome ``trace_event`` JSON
(load it at ui.perfetto.dev); the report's ``device_idle_frac`` /
``device_idle_s`` then come *measured* from the per-device
``device.solve`` spans instead of the host-side estimate.
``--assert-trace`` additionally hard-fails unless every completed
request has its full submit->scatter span chain and at least two
devices show non-empty ``device.solve`` tracks (CI runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).  Without
tracing the bench asserts the scheduler's span path stayed a no-op
(``spans_recorded == 0``) — the observability layer must cost nothing
when off.

    python -m repro.serve_lp.bench --smoke
    python -m repro.serve_lp.bench --smoke --open-loop --assert-overlap
    python -m repro.serve_lp.bench --smoke --open-loop \
        --trace-out trace.json --assert-trace
    python -m repro.serve_lp.bench --requests 2000 --rate 5000 \
        --method kernel --max-batch 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve_lp.scheduler import BatchScheduler
from repro.solver import SolverSpec

KINDS = ("feasible", "infeasible", "degenerate")


@dataclasses.dataclass
class BenchConfig:
    requests: int = 2000
    rate: float = 5000.0          # target submit rate, LPs/s
    m_min: int = 8
    m_max: int = 1024
    kind_mix: Tuple[float, float, float] = (0.8, 0.1, 0.1)
    method: str = "rgb"
    max_batch: int = 64
    max_wait_s: float = 0.02
    tile: int = 16
    chunk: int = 0
    seed: int = 0
    check: int = 8                # requests re-solved directly, 0 = off
    warmup: bool = True           # pre-compile executables, reset counters
    interpret: Optional[bool] = None
    pipeline: bool = True         # overlap assembly with in-flight solves
    max_inflight: int = 2         # dispatch backpressure bound
    open_loop: bool = False       # saturating burst: ignore `rate`
    assert_overlap: bool = False  # require >=2 flushes seen in flight
    sharding: str = "mesh"        # flush executable path: mesh | pmap
    assert_fused: bool = False    # require >=1 cross-bucket fused flush
    # --rpc mode: drive the HTTP front end instead of in-process submit
    rpc: bool = False
    rpc_clients: int = 8          # closed-loop client threads
    rpc_burst: int = 0            # open-loop overload posts (0 = 2x requests)
    rpc_target_p99_ms: Optional[float] = None   # enable SLO controller
    rpc_p99_bound_ms: float = 2500.0            # --assert-rpc bound
    assert_rpc: bool = False      # enforce p99 + shed-rate bounds
    trace: bool = False           # run under a repro.obs tracer
    trace_out: Optional[str] = None   # write Chrome trace JSON here
    assert_trace: bool = False    # enforce span chains + >=2 dev tracks


def smoke_config() -> BenchConfig:
    """CI-sized run: a few hundred LPs, m capped so only a handful of
    executables compile; finishes well inside 30s on CPU."""
    return BenchConfig(requests=160, rate=2000.0, m_max=512,
                       max_batch=32, max_wait_s=0.01, check=8)


# -- deterministic request generators (numpy mirrors of core.lp) ---------

def _feasible(rng: np.random.Generator, m: int, slack_lo: float = 0.1):
    xstar = rng.uniform(-50.0, 50.0, 2)
    theta = rng.uniform(0.0, 2.0 * np.pi, m)
    A = np.stack([np.cos(theta), np.sin(theta)], axis=-1)
    s = rng.uniform(slack_lo, 5.0, m)
    b = A @ xstar + s
    phi = rng.uniform(0.0, 2.0 * np.pi)
    c = np.array([np.cos(phi), np.sin(phi)])
    return (A.astype(np.float32), b.astype(np.float32),
            c.astype(np.float32))


def _degenerate(rng: np.random.Generator, m: int):
    """Every constraint tight at one point: the feasible set collapses to
    a single massively-degenerate vertex."""
    A, b, c = _feasible(rng, m)
    xstar = rng.uniform(-50.0, 50.0, 2).astype(np.float32)
    b = (A @ xstar).astype(np.float32)
    return A, b, c


def _infeasible(rng: np.random.Generator, m: int):
    A, b, c = _feasible(rng, m)
    A[0] = (1.0, 0.0)
    b[0] = -1.0
    A[1] = (-1.0, 0.0)
    b[1] = -1.0
    return A, b, c


_GEN = {"feasible": _feasible, "infeasible": _infeasible,
        "degenerate": _degenerate}


def make_request(cfg: BenchConfig, i: int):
    """Request #i of the stream — a pure function of (seed, i)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, i, 0x52E41]))
    sizes = [m for m in (8, 16, 32, 64, 128, 256, 512, 1024)
             if cfg.m_min <= m <= cfg.m_max]
    m = int(sizes[rng.integers(len(sizes))])
    kind = KINDS[rng.choice(3, p=np.asarray(cfg.kind_mix))]
    A, b, c = _GEN[kind](rng, max(m, 2))
    return A, b, c, kind


# -- the open-loop driver ------------------------------------------------

def _warmup(cfg: BenchConfig, sched: BatchScheduler,
            quiet: bool) -> None:
    """Pre-compile the steady-state executables — every (m-bucket,
    b_pad-rung) pair traffic can produce, wait-triggered partial flushes
    included — then zero all counters so the report shows warm serving
    behaviour."""
    from repro.serve_lp.buckets import bucket_batch, bucket_m
    from repro.serve_lp.metrics import ServeMetrics
    t0 = time.perf_counter()
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xAA]))
    buckets = sorted({bucket_m(m, base=sched.bucket_base) for m in
                      (8, 16, 32, 64, 128, 256, 512, 1024)
                      if cfg.m_min <= m <= cfg.m_max})
    # b_pad ladder: a flush holds 1..max_batch requests, so its b_pad is
    # one of the unit*2^k rungs up to bucket_batch(max_batch, unit).
    rungs, b = set(), sched.batch_unit
    top = bucket_batch(cfg.max_batch, sched.batch_unit)
    while b <= top:
        rungs.add(min(b, cfg.max_batch))
        b *= 2
    for bm in buckets:
        for n in sorted(rungs):
            futs = [sched.submit(*_feasible(rng, min(bm, cfg.m_max)))
                    for _ in range(n)]
            sched.flush()
            for f in futs:
                f.result(timeout=300.0)
    sched.cache.reset_stats()
    sched.metrics = ServeMetrics()
    if not quiet:
        print(f"[serve_lp.bench] warmup built {len(sched.cache)} "
              f"executables in {time.perf_counter() - t0:.2f}s")


def run_traffic(cfg: BenchConfig, *, quiet: bool = False
                ) -> Tuple[Dict, BatchScheduler]:
    spec = SolverSpec(backend=cfg.method, tile=cfg.tile, chunk=cfg.chunk,
                      interpret=cfg.interpret)
    traced = cfg.trace or cfg.trace_out is not None or cfg.assert_trace
    tracer = None
    if traced:
        from repro.obs import Tracer
        # Ring sized so a full smoke run (6 spans per request upper
        # bound) survives without wraparound — dropped spans would break
        # the --assert-trace chain check.
        tracer = Tracer(enabled=True,
                        capacity=max(16384, 8 * cfg.requests))
    sched = BatchScheduler(spec, max_batch=cfg.max_batch,
                           max_wait_s=cfg.max_wait_s,
                           pipeline=cfg.pipeline,
                           max_inflight=cfg.max_inflight,
                           sharding=cfg.sharding,
                           tracer=tracer)
    if cfg.warmup:
        _warmup(cfg, sched, quiet)
        if traced:
            sched.tracer.buffer.clear()   # measured phase only
    futures: List = []
    t_wall0 = time.perf_counter()
    with sched:
        t0 = time.perf_counter()
        for i in range(cfg.requests):
            if not cfg.open_loop:
                target = t0 + i / cfg.rate
                now = time.perf_counter()
                if target > now:
                    time.sleep(target - now)
            A, b, c, _ = make_request(cfg, i)
            futures.append(sched.submit(A, b, c))
    # context exit stops the timer thread, flushes the tail and joins
    # every in-flight flush
    results = [f.result(timeout=60.0) for f in futures]
    wall = time.perf_counter() - t_wall0

    if cfg.check:
        _check_against_direct(cfg, results)
    snap = sched.metrics.snapshot(sched.cache.stats())
    snap["wall_s"] = wall
    snap["n_feasible"] = sum(r.feasible for r in results)
    if traced:
        snap.update(_trace_report(cfg, sched, quiet))
    else:
        # The no-trace contract: with tracing off the scheduler's span
        # path must be a pure no-op — nothing ever committed to a ring.
        stats = sched.tracer.stats()
        assert stats["spans_recorded"] == 0, (
            "tracing disabled but the scheduler recorded "
            f"{stats['spans_recorded']} spans; the no-trace path is "
            "not free")
    if not quiet:
        print(f"[serve_lp.bench] {cfg.requests} requests "
              f"({snap['n_feasible']} feasible) wall={wall:.2f}s "
              f"pipeline={'on' if cfg.pipeline else 'off'}")
        print(sched.metrics.format_report(sched.cache.stats()))
        if cfg.check:
            print(f"[serve_lp.bench] check ok: {cfg.check} requests "
                  "match a direct solver-spec solve")
    if cfg.assert_overlap:
        assert cfg.pipeline, "--assert-overlap needs pipelining enabled"
        assert snap["inflight_max"] >= 2, (
            "pipelined serve loop never had 2 flushes in flight "
            f"(inflight_max={snap['inflight_max']}); assembly did not "
            "overlap an in-flight solve")
        assert snap["overlapped_dispatches"] >= 1, (
            "no dispatch ever overlapped an in-flight solve")
        if not quiet:
            print(f"[serve_lp.bench] overlap ok: max in-flight depth "
                  f"{snap['inflight_max']}, "
                  f"{snap['overlapped_dispatches']} overlapped "
                  "dispatches")
    if cfg.assert_fused:
        assert cfg.sharding == "mesh", "--assert-fused needs mesh sharding"
        assert snap["fused_flushes"] >= 1, (
            "no flush ever fused multiple buckets "
            f"(fused_flushes={snap['fused_flushes']}); underfull "
            "buckets were launched separately")
        assert snap["fused_buckets"] >= 2, (
            f"fused flushes covered only {snap['fused_buckets']} "
            "buckets")
        if not quiet:
            print(f"[serve_lp.bench] fusing ok: {snap['fused_flushes']} "
                  f"fused flushes covering {snap['fused_buckets']} "
                  "buckets")
    return snap, sched


def _trace_report(cfg: BenchConfig, sched: BatchScheduler,
                  quiet: bool) -> Dict:
    """Post-run span analysis: write the Chrome trace, measure device
    idleness from the ``device.solve`` tracks, and (``--assert-trace``)
    enforce the full-chain + multi-device contract."""
    from repro.obs import check_span_chains, device_idle
    from repro.obs.export import write_chrome_trace
    spans = sched.tracer.spans()
    chains = check_span_chains(spans)
    idle = device_idle(spans)
    if cfg.trace_out:
        write_chrome_trace(spans, cfg.trace_out)
        if not quiet:
            print(f"[serve_lp.bench] wrote {len(spans)} spans to "
                  f"{cfg.trace_out} (load at ui.perfetto.dev)")
    dev_tracks = {d: v["n_solves"] for d, v in idle["devices"].items()
                  if v["n_solves"] > 0}
    if not quiet:
        print(f"[serve_lp.bench] trace: {chains['complete']} complete "
              f"request chains over {chains['flushes']} flushes, "
              f"{len(chains['problems'])} problems; measured device "
              f"idle {100 * idle['idle_frac']:.1f}% over "
              f"{len(dev_tracks)} device tracks")
    if cfg.assert_trace:
        assert chains["complete"] >= cfg.requests, (
            f"only {chains['complete']} of {cfg.requests} completed "
            "requests have request spans in the ring "
            f"(dropped={sched.tracer.stats()['ring_dropped']})")
        assert not chains["problems"], (
            "span chains incomplete or mis-ordered: "
            + "; ".join(chains["problems"][:5]))
        assert len(dev_tracks) >= 2, (
            f"only {len(dev_tracks)} device(s) show device.solve "
            "tracks; run under XLA_FLAGS="
            "--xla_force_host_platform_device_count=4 (or on a "
            "multi-device host) for --assert-trace")
        if not quiet:
            print(f"[serve_lp.bench] trace ok: all {cfg.requests} "
                  f"chains complete, {len(dev_tracks)} device tracks "
                  "non-empty")
    return {
        # Measured from per-device solve spans — supersedes the
        # host-side device_idle_s_est gauge when tracing is on.
        "device_idle_frac": idle["idle_frac"],
        "device_idle_s": idle["idle_s"],
        "device_busy_s": idle["busy_s"],
        "device_window_s": idle["window_s"],
        "device_tracks": dev_tracks,
        "trace_complete_chains": chains["complete"],
        "trace_problems": len(chains["problems"]),
        "trace_spans": len(spans),
    }


def _check_against_direct(cfg: BenchConfig, results: List) -> None:
    """Re-solve a deterministic subset directly and compare."""
    from repro.core import make_batch
    from repro.solver import get_solver
    solver = get_solver(SolverSpec(backend=cfg.method, tile=cfg.tile,
                                   chunk=cfg.chunk,
                                   interpret=cfg.interpret))
    idxs = np.linspace(0, cfg.requests - 1, cfg.check).astype(int)
    for i in idxs:
        A, b, c, _ = make_request(cfg, int(i))
        sol = solver.solve(make_batch(A, b, c))
        r = results[int(i)]
        assert bool(sol.feasible[0]) == r.feasible, (
            f"request {i}: feasible mismatch")
        if r.feasible:
            np.testing.assert_allclose(np.asarray(sol.x[0]), r.x,
                                       rtol=1e-5, atol=1e-5)


# -- the RPC (HTTP) driver ------------------------------------------------

BURST_TENANT = "burst"          # overload-phase tenant: tiny quota
BURST_QUOTA = (200.0, 64.0)     # (rate LPs/s, burst) for that tenant


def _rpc_post(conn, obj, headers=None):
    """POST /v1/solve on a keep-alive connection; (status, parsed)."""
    import json
    conn.request("POST", "/v1/solve", json.dumps(obj),
                 {"Content-Type": "application/json", **(headers or {})})
    resp = conn.getresponse()
    return resp.status, json.loads(resp.read() or b"{}")


def _rpc_problem(cfg: BenchConfig, i: int):
    A, b, c, _ = make_request(cfg, i)
    return {"A": A.tolist(), "b": b.tolist(), "c": c.tolist()}


def run_rpc_traffic(cfg: BenchConfig, *, quiet: bool = False) -> Dict:
    """Drive the HTTP front end: closed-loop latency phase (N client
    threads, keep-alive), then an open-loop overload phase under a
    deliberately tiny tenant quota so shedding is observable, then a
    /metrics scrape validated as Prometheus text.  Returns a report
    dict; ``cfg.assert_rpc`` turns the p99/shed/correctness claims into
    hard checks (the CI smoke)."""
    import http.client
    import threading as _threading
    from repro.serve_lp.rpc import (AdmissionPolicy, QuotaManager,
                                    make_frontend, validate_exposition)
    from repro.serve_lp.rpc.server import run_in_thread

    spec = SolverSpec(backend=cfg.method, tile=cfg.tile, chunk=cfg.chunk,
                      interpret=cfg.interpret)
    frontend = make_frontend(
        spec, max_batch=cfg.max_batch, max_wait_s=cfg.max_wait_s,
        max_inflight=cfg.max_inflight, pipeline=cfg.pipeline,
        policy=AdmissionPolicy(
            m_max=max(cfg.m_max, 8), batch_max=max(4 * cfg.max_batch, 256),
            max_pending=1024, max_queue_age_s=0.5),
        quotas=QuotaManager(rate=1e6, burst=1e6,
                            per_tenant={BURST_TENANT: BURST_QUOTA}),
        target_p99_s=(cfg.rpc_target_p99_ms / 1e3
                      if cfg.rpc_target_p99_ms is not None else None))
    port, stop = run_in_thread(frontend)
    t_wall0 = time.perf_counter()
    try:
        def connect():
            return http.client.HTTPConnection("127.0.0.1", port,
                                              timeout=120)

        # Warmup: compile the bucket-ladder executables through the
        # network path (one size-triggered full batch + one
        # wait-triggered single per bucket) so the measured phases see
        # warm serving behaviour, as the in-process bench does.
        if cfg.warmup:
            t0 = time.perf_counter()
            conn = connect()
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, 0xAB]))
            sizes = [m for m in (8, 16, 32, 64, 128, 256, 512, 1024)
                     if cfg.m_min <= m <= cfg.m_max]
            for m in sizes:
                A, b, c = _feasible(rng, m)
                prob = {"A": A.tolist(), "b": b.tolist(), "c": c.tolist()}
                st, _ = _rpc_post(conn, {"problems":
                                         [prob] * cfg.max_batch})
                assert st == 200, f"warmup batch post failed: {st}"
                st, _ = _rpc_post(conn, prob)
                assert st == 200, f"warmup single post failed: {st}"
            conn.close()
            if not quiet:
                print(f"[serve_lp.bench --rpc] warmup over HTTP in "
                      f"{time.perf_counter() - t0:.2f}s")

        # Phase 1 — closed loop: client threads issue requests
        # back-to-back over keep-alive connections; per-request wall
        # latency measured client-side.
        n_clients = max(1, cfg.rpc_clients)
        lat_ms: List[float] = []
        closed_errors: List[int] = []
        lock = _threading.Lock()

        def client(worker: int) -> None:
            conn = connect()
            my_lat, my_err = [], []
            for i in range(worker, cfg.requests, n_clients):
                t = time.perf_counter()
                st, _body = _rpc_post(conn, _rpc_problem(cfg, i))
                dt = (time.perf_counter() - t) * 1e3
                if st == 200:
                    my_lat.append(dt)
                else:
                    my_err.append(st)
            conn.close()
            with lock:
                lat_ms.extend(my_lat)
                closed_errors.extend(my_err)

        threads = [_threading.Thread(target=client, args=(w,))
                   for w in range(n_clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        closed_wall = time.perf_counter() - t0

        # Phase 2 — open-loop overload: hammer from a tiny-quota tenant
        # so admission demonstrably sheds with 429 instead of queueing.
        burst_n = cfg.rpc_burst or 2 * cfg.requests
        statuses: List[int] = []
        retry_after_seen: List[bool] = []

        def burster(worker: int) -> None:
            import json as _json
            conn = connect()
            my_st, my_ra = [], []
            for i in range(worker, burst_n, 16):
                conn.request("POST", "/v1/solve",
                             _json.dumps(_rpc_problem(cfg, i)),
                             {"X-Tenant": BURST_TENANT})
                resp = conn.getresponse()
                resp.read()
                my_st.append(resp.status)
                if resp.status == 429:
                    my_ra.append(resp.getheader("Retry-After")
                                 is not None)
            conn.close()
            with lock:
                statuses.extend(my_st)
                retry_after_seen.extend(my_ra)

        bursters = [_threading.Thread(target=burster, args=(w,))
                    for w in range(16)]
        for t in bursters:
            t.start()
        for t in bursters:
            t.join()
        accepted = sum(1 for s in statuses if s == 200)
        shed = sum(1 for s in statuses if s == 429)
        other = len(statuses) - accepted - shed

        # Phase 3 — scrape /metrics and validate the exposition.
        conn = connect()
        conn.request("GET", "/metrics")
        resp = conn.getresponse()
        metrics_text = resp.read().decode()
        assert resp.status == 200
        validate_exposition(metrics_text)

        # Correctness: a deterministic sample of closed-loop requests
        # re-posted and compared against a direct solver-spec solve.
        if cfg.check:
            from repro.core import make_batch
            from repro.solver import get_solver
            solver = get_solver(spec)
            reconn = connect()
            idxs = np.linspace(0, cfg.requests - 1,
                               cfg.check).astype(int)
            for i in idxs:
                A, b, c, _ = make_request(cfg, int(i))
                st, body = _rpc_post(reconn, _rpc_problem(cfg, int(i)))
                assert st == 200, f"check repost {i} failed: {st}"
                sol = solver.solve(make_batch(A, b, c))
                r = body["result"]
                assert bool(sol.feasible[0]) == r["feasible"]
                if r["feasible"]:
                    np.testing.assert_array_equal(
                        np.asarray(sol.x[0]),
                        np.asarray(r["x"], np.float32).reshape(2))
            reconn.close()
        conn.close()
    finally:
        stop()

    lat = np.asarray(sorted(lat_ms)) if lat_ms else np.zeros(1)
    report = {
        "rpc_port": port,
        "wall_s": time.perf_counter() - t_wall0,
        "closed_loop": {
            "requests": cfg.requests,
            "ok": len(lat_ms),
            "errors": len(closed_errors),
            "wall_s": closed_wall,
            "rps": (len(lat_ms) / closed_wall if closed_wall > 0
                    else 0.0),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
        },
        "overload": {
            "requests": burst_n,
            "accepted": accepted,
            "shed_429": shed,
            "other": other,
            "shed_rate": shed / max(1, len(statuses)),
            "retry_after_on_429": (all(retry_after_seen)
                                   if retry_after_seen else False),
        },
        "slo": ({str(k): dataclasses.asdict(v)
                 for k, v in frontend.slo.plans().items()}
                if frontend.slo is not None else None),
        "metrics_valid": True,
        "metrics_bytes": len(metrics_text),
    }
    if not quiet:
        c, o = report["closed_loop"], report["overload"]
        print(f"[serve_lp.bench --rpc] closed-loop: {c['ok']}/"
              f"{c['requests']} ok at {c['rps']:.1f} req/s, "
              f"p50={c['p50_ms']:.1f}ms p99={c['p99_ms']:.1f}ms, "
              f"{c['errors']} errors")
        print(f"[serve_lp.bench --rpc] overload: {o['accepted']} "
              f"accepted, {o['shed_429']} shed with 429 "
              f"({100 * o['shed_rate']:.0f}%), {o['other']} other")
        print(f"[serve_lp.bench --rpc] /metrics: valid Prometheus "
              f"text, {report['metrics_bytes']} bytes")
    if cfg.assert_rpc:
        assert not closed_errors, (
            f"closed-loop phase had non-200 responses: "
            f"{sorted(set(closed_errors))}")
        assert report["closed_loop"]["p99_ms"] <= cfg.rpc_p99_bound_ms, (
            f"closed-loop p99 {report['closed_loop']['p99_ms']:.1f}ms "
            f"exceeds the bound {cfg.rpc_p99_bound_ms}ms")
        assert shed >= 1, "overload phase never shed with 429"
        assert accepted >= 1, "overload phase never admitted anything"
        assert other == 0, f"unexpected statuses in overload: {other}"
        assert report["overload"]["retry_after_on_429"], (
            "429 responses were missing Retry-After")
        if not quiet:
            print("[serve_lp.bench --rpc] assertions ok: p99 within "
                  "bound, overload shed with 429 + Retry-After, "
                  "answers match direct solves")
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized preset (overrides size args)")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--rate", type=float, default=5000.0)
    ap.add_argument("--m-max", type=int, default=1024)
    ap.add_argument("--method", default="rgb",
                    choices=("rgb", "kernel", "naive"))
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", type=int, default=8)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip executable pre-compilation")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="stop-and-go serve loop (A/B the overlap win)")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="dispatch backpressure bound (pipelined mode)")
    ap.add_argument("--open-loop", action="store_true",
                    help="saturating burst: submit with no rate throttle")
    ap.add_argument("--assert-overlap", action="store_true",
                    help="fail unless >=2 flushes were in flight at once")
    ap.add_argument("--sharding", default="mesh",
                    choices=("mesh", "pmap"),
                    help="flush executable path: mesh (shard_map, "
                         "uneven shards, cross-bucket fusing) or the "
                         "legacy pmap escape hatch")
    ap.add_argument("--assert-fused", action="store_true",
                    help="fail unless >=1 flush fused multiple "
                         "m-buckets into one launch (mesh only)")
    ap.add_argument("--trace", action="store_true",
                    help="run under a repro.obs tracer (measured "
                         "device-idle numbers in the report)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the span ring as Chrome trace_event "
                         "JSON to PATH (implies --trace)")
    ap.add_argument("--assert-trace", action="store_true",
                    help="fail unless every completed request has its "
                         "full span chain and >=2 devices show "
                         "device.solve tracks (implies --trace)")
    ap.add_argument("--rpc", action="store_true",
                    help="drive the HTTP front end (closed-loop latency "
                         "phase + open-loop overload phase + /metrics "
                         "scrape) instead of in-process submit")
    ap.add_argument("--rpc-clients", type=int, default=8,
                    help="closed-loop client threads (--rpc)")
    ap.add_argument("--rpc-burst", type=int, default=0,
                    help="overload-phase posts (--rpc; 0 = 2x requests)")
    ap.add_argument("--rpc-target-p99-ms", type=float, default=None,
                    help="enable the SLO controller at this target "
                         "(--rpc)")
    ap.add_argument("--rpc-p99-bound-ms", type=float, default=2500.0,
                    help="closed-loop p99 bound --assert-rpc enforces")
    ap.add_argument("--assert-rpc", action="store_true",
                    help="fail unless p99 is within bound, overload "
                         "sheds with 429 + Retry-After, and answers "
                         "match direct solves (--rpc)")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = smoke_config()
        cfg.method = args.method
        cfg.seed = args.seed
    else:
        cfg = BenchConfig(
            requests=args.requests, rate=args.rate, m_max=args.m_max,
            method=args.method, max_batch=args.max_batch,
            max_wait_s=args.max_wait_ms / 1e3, tile=args.tile,
            chunk=args.chunk, seed=args.seed, check=args.check)
    cfg.warmup = not args.no_warmup
    cfg.pipeline = not args.no_pipeline
    cfg.max_inflight = args.max_inflight
    cfg.open_loop = args.open_loop
    cfg.assert_overlap = args.assert_overlap
    cfg.sharding = args.sharding
    cfg.assert_fused = args.assert_fused
    cfg.trace = args.trace
    cfg.trace_out = args.trace_out
    cfg.assert_trace = args.assert_trace
    cfg.rpc = args.rpc
    cfg.rpc_clients = args.rpc_clients
    cfg.rpc_burst = args.rpc_burst
    cfg.rpc_target_p99_ms = args.rpc_target_p99_ms
    cfg.rpc_p99_bound_ms = args.rpc_p99_bound_ms
    cfg.assert_rpc = args.assert_rpc
    if cfg.rpc:
        run_rpc_traffic(cfg)
    else:
        run_traffic(cfg)


if __name__ == "__main__":
    main()
