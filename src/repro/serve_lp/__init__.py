"""Batched LP serving subsystem.

Turns the batch 2-D LP solver stack into a service: callers submit
individual LPs of arbitrary constraint count and get futures back; a
scheduler aggregates them into shape-bucketed super-batches, solves each
flush through a cached executable (sharded across devices when more than
one is visible) and scatters results to the futures in submission order.

    scheduler (submit/flush policy, pipelined dispatch + completion,
               cross-bucket fused flush units)
        -> buckets (shape ladder + executable cache)
        -> mesh_layout (MeshLayout planner: uneven per-device shards,
           grouped launches, planner-owned padding)
        -> sharding (dispatch/complete Executables; shard_map over the
           planned mesh, single-device jit fallback, legacy pmap
           escape hatch)
        -> futures (per-request LPResult)

The serve loop is pipelined by default: flush dispatch is asynchronous
(device handles, no host sync) and a completion worker scatters
results, so the host assembles the next super-batch while the device
solves the current one; ``BatchScheduler(..., pipeline=False)``
restores the stop-and-go loop and ``max_inflight`` bounds the
dispatch depth (backpressure).

Use :class:`BatchScheduler` when requests arrive one at a time (serving,
simulation agents, RPC handlers); build a
:class:`~repro.solver.SolverSpec` and call its Solver directly when you
already hold one uniform batch.  The scheduler takes the same spec —
``BatchScheduler(SolverSpec(...))`` — and embeds it in every flush's
:class:`ExecSpec` cache key.
"""
from repro.serve_lp.buckets import (SHARDING_MODES, ExecSpec,
                                    ExecutableCache, bucket_batch,
                                    bucket_m, shape_ladder)
from repro.serve_lp.mesh_layout import (LaunchGroup, MeshLayout, make_mesh,
                                        plan_layout)
from repro.serve_lp.metrics import ServeMetrics
from repro.serve_lp.scheduler import BatchScheduler, LPResult
from repro.serve_lp.sharding import (Executable, as_executable,
                                     build_executable)
from repro.solver import SolverSpec

__all__ = [
    "BatchScheduler", "Executable", "ExecSpec", "ExecutableCache",
    "LPResult", "LaunchGroup", "MeshLayout", "SHARDING_MODES",
    "ServeMetrics", "SolverSpec", "as_executable", "bucket_batch",
    "bucket_m", "build_executable", "make_mesh", "plan_layout",
    "shape_ladder",
]
