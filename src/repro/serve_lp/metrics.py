"""Serving metrics: latency percentiles, throughput, padding waste, and
pipeline observability (in-flight depth, dispatch/complete stage times,
device-idle-gap estimate, error counters).

Everything is recorded under one lock (submit, flush, timer and
completion threads all write here) and summarised by
:meth:`ServeMetrics.snapshot`.  Padding waste is tracked two ways
because they answer different questions:

* *problem* waste — neutral problems added to pad the batch dimension;
  these cost kernel time directly;
* *cell* waste — padded constraint rows (bucket_m - m per request) plus
  all cells of padding problems; this is the VMEM/bandwidth overhead of
  shape bucketing.

The pipelined serve loop adds a second family of questions — *is the
device actually kept busy?* — answered by:

* the **in-flight gauge** (``record_dispatch``/``record_complete``):
  current and maximum concurrently dispatched flushes, plus how many
  dispatches overlapped an already-in-flight solve;
* the **device-idle estimate**: summed gaps between one flush's
  completion and the next dispatch while nothing was in flight — the
  stop-and-go time the pipeline exists to remove;
* per-flush **assemble vs solve seconds** (host packing time vs
  dispatch-to-complete device service time).

Latencies are kept in a true bounded *reservoir*: once full, each new
sample replaces a reservoir slot with probability k/n via a
deterministic counter-seeded LCG (no ``random`` on the hot path), so
long runs stay uniformly represented instead of biased toward the
start; ``latency_seen`` vs ``latency_samples`` in the snapshot shows
how much sampling occurred.

Alongside the reservoir, four fixed log-spaced **histogram** families
(request latency, queue wait, per-flush solve and total flush
duration) accumulate cumulative bucket counters — the Prometheus
``_bucket``/``_sum``/``_count`` representation, mergeable across
scrapes and servers in ways a percentile gauge never is.  Reservoir
percentiles remain the *local* high-resolution view; histograms are
the *exported* view.  Each histogram keeps one exemplar (last
observed value + trace id) per bucket, surfaced as OpenMetrics-style
exemplars on the latency families.

Two observability hooks close the loop with ``repro.obs``:
``set_error_hook`` routes every counted error kind to the flight
recorder, and :meth:`snapshot` now computes its percentiles from the
same lock-held copy as every other field — a ``/metrics`` scrape
racing the completion worker sees one consistent state, never a
reservoir mid-update or torn dispatch/complete pairs.
"""
from __future__ import annotations

import math
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

_MAX_LATENCIES = 200_000  # reservoir size; plenty for bench runs

# Knuth MMIX LCG constants — the deterministic index stream for
# reservoir replacement (cheap, lock-held, no `random` import).
_LCG_MUL = 6364136223846793005
_LCG_INC = 1442695040888963407
_LCG_MASK = (1 << 64) - 1


def log_bounds(lo: float, hi: float, per_decade: int = 3
               ) -> Tuple[float, ...]:
    """Log-spaced histogram bucket bounds from ``lo`` to at least
    ``hi``, ``per_decade`` bounds per decade.  Fixed at construction —
    Prometheus histograms must keep stable ``le`` labels across
    scrapes."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = int(math.ceil(per_decade * math.log10(hi / lo))) + 1
    out = [round(lo * 10 ** (i / per_decade), 12) for i in range(n)]
    return tuple(out)


# 100µs .. ~100s, 3 buckets/decade: 19 bounds (+Inf implicit) covers
# sub-ms kernel solves through multi-second saturated-queue tails.
DEFAULT_DURATION_BOUNDS = log_bounds(1e-4, 100.0, per_decade=3)

# The four exported duration families.  Names are the *suffix-free*
# Prometheus family names; the exposition renderer adds the prefix.
HIST_FAMILIES = (
    "request_latency_seconds",   # submit -> result, per request
    "queue_wait_seconds",        # submit -> flush assembly, per request
    "solve_duration_seconds",    # dispatch -> complete, per flush
    "flush_duration_seconds",    # assemble start -> complete, per flush
)


class _Histogram:
    """Cumulative-bucket histogram with per-bucket exemplars.

    Not self-locking: observations happen under the owning
    :class:`ServeMetrics` lock (one lock for the whole metrics struct
    keeps snapshots consistent).  ``counts[i]`` is the number of
    observations ``<= bounds[i]``-noncumulative; the renderer
    accumulates.  ``exemplars[i]`` keeps the last ``(value, trace_id)``
    landing in bucket i (trace-id exemplars on the latency families).
    """

    __slots__ = ("bounds", "counts", "overflow", "sum", "count",
                 "exemplars")

    def __init__(self, bounds: Tuple[float, ...] =
                 DEFAULT_DURATION_BOUNDS):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must strictly increase")
        self.counts = [0] * len(self.bounds)
        self.overflow = 0            # observations > bounds[-1] (+Inf)
        self.sum = 0.0
        self.count = 0
        self.exemplars: Dict[int, Tuple[float, str]] = {}

    def observe(self, value: float,
                trace_id: Optional[str] = None) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        idx = self._bucket_of(v)
        if idx is None:
            self.overflow += 1
            idx = len(self.bounds)
        else:
            self.counts[idx] += 1
        if trace_id:
            self.exemplars[idx] = (v, trace_id)

    def _bucket_of(self, v: float) -> Optional[int]:
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo if lo < len(self.bounds) else None

    def state(self) -> Dict[str, Any]:
        """Copy for snapshots: bounds, *cumulative* counts (aligned
        with bounds + the +Inf bucket), sum/count, exemplars keyed by
        bucket index."""
        cum: List[int] = []
        acc = 0
        for c in self.counts:
            acc += c
            cum.append(acc)
        cum.append(acc + self.overflow)
        return {
            "bounds": list(self.bounds),
            "cumulative": cum,
            "sum": self.sum,
            "count": self.count,
            "exemplars": {i: list(e) for i, e in self.exemplars.items()},
        }


class ServeMetrics:
    def __init__(self, max_latency_samples: int = _MAX_LATENCIES):
        self._lock = threading.Lock()
        self._latencies: List[float] = []
        self._max_latencies = int(max_latency_samples)
        self.lat_seen = 0            # latencies offered (>= kept)
        self._lat_rng = 0x9E3779B97F4A7C15
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self.n_solved = 0
        self.n_flushes = 0
        self.flush_reasons: Dict[str, int] = {}
        # Sharding/fusing observability: total device launches (a mesh
        # flush may group into 1-2 sub-mesh launches; pmap/jit is 1),
        # fused multi-bucket flush units, how many m-buckets those
        # folded together, and packed rows dispatched per device index.
        self.launches = 0
        self.fused_flushes = 0
        self.fused_buckets = 0
        self.rows_by_device: List[int] = []
        self.problems_real = 0
        self.problems_padded = 0
        self.cells_valid = 0
        self.cells_total = 0
        self.solve_seconds = 0.0
        self.assemble_seconds = 0.0
        # Pipeline gauges/counters.
        self.n_dispatched = 0
        self.inflight_now = 0
        self.inflight_max = 0
        self.overlapped_dispatches = 0
        self.device_idle_s = 0.0
        self._t_last_complete: Optional[float] = None
        # Error counters by kind (timer_flush, solve, ...); each kind
        # warns once so failures are loud without spamming.
        self.errors: Dict[str, int] = {}
        self._warned: set = set()
        # Exported histogram families (observed under the same lock).
        self.hists: Dict[str, _Histogram] = {
            name: _Histogram() for name in HIST_FAMILIES}
        # Observability hook: called (outside the lock) with the error
        # kind after each record_error — the flight recorder's trigger.
        self._error_hook: Optional[Callable[[str], Any]] = None

    def set_error_hook(self,
                       hook: Optional[Callable[[str], Any]]) -> None:
        """Install (or clear) a callable invoked with the error kind on
        every :meth:`record_error` — outside the metrics lock, and
        exception-proofed (a broken hook never takes down the thread
        that hit the original error)."""
        self._error_hook = hook

    def touch_clock(self) -> None:
        """Mark traffic activity (throughput is solved / active window)."""
        now = time.perf_counter()
        with self._lock:
            if self._t0 is None:
                self._t0 = now
            self._t_last = now

    def record_latency(self, seconds: float,
                       trace_id: Optional[str] = None) -> None:
        """Add one sample to the bounded reservoir and the request
        latency histogram (``trace_id`` becomes the bucket exemplar).

        Below capacity every sample is kept; past it, sample n replaces
        a uniformly chosen slot with probability k/n (classic reservoir
        sampling, index drawn from a deterministic LCG), so percentiles
        of long runs reflect the whole run, not its first k samples.
        """
        with self._lock:
            self.lat_seen += 1
            self.hists["request_latency_seconds"].observe(
                seconds, trace_id)
            if len(self._latencies) < self._max_latencies:
                self._latencies.append(seconds)
                return
            self._lat_rng = (self._lat_rng * _LCG_MUL + _LCG_INC) \
                & _LCG_MASK
            j = self._lat_rng % self.lat_seen
            if j < self._max_latencies:
                self._latencies[j] = seconds

    def record_queue_wait(self, seconds: float,
                          trace_id: Optional[str] = None) -> None:
        """One request's submit -> flush-assembly wait (observed at
        assemble time for every member of the flush)."""
        with self._lock:
            self.hists["queue_wait_seconds"].observe(seconds, trace_id)

    def record_queue_waits(
            self, waits: List[Tuple[float, Optional[str]]]) -> None:
        """Batch form of :meth:`record_queue_wait` — one lock hold per
        flush instead of one per member request."""
        with self._lock:
            h = self.hists["queue_wait_seconds"]
            for seconds, trace_id in waits:
                h.observe(seconds, trace_id)

    def record_dispatch(self) -> int:
        """One flush handed to the device; returns the in-flight depth
        including it.  Dispatches that find the device already busy
        count as *overlapped*; dispatches that find it idle accrue the
        idle gap since the previous completion."""
        now = time.perf_counter()
        with self._lock:
            self.n_dispatched += 1
            self.inflight_now += 1
            if self.inflight_now > self.inflight_max:
                self.inflight_max = self.inflight_now
            if self.inflight_now > 1:
                self.overlapped_dispatches += 1
            elif self._t_last_complete is not None:
                self.device_idle_s += max(0.0,
                                          now - self._t_last_complete)
            return self.inflight_now

    def record_complete(self) -> int:
        """One dispatched flush fully completed; returns the remaining
        in-flight depth."""
        now = time.perf_counter()
        with self._lock:
            if self.inflight_now > 0:
                self.inflight_now -= 1
            self._t_last_complete = now
            return self.inflight_now

    def record_error(self, kind: str, warn: Optional[str] = None) -> None:
        """Count an error by kind; the first error of each kind emits
        ``warn`` as a RuntimeWarning (once), so broken tables or
        executables are visible instead of silently swallowed."""
        with self._lock:
            self.errors[kind] = self.errors.get(kind, 0) + 1
            first = kind not in self._warned
            self._warned.add(kind)
            hook = self._error_hook
        if first and warn is not None:
            try:
                warnings.warn(warn, RuntimeWarning, stacklevel=2)
            except Exception:
                # Warning filters may escalate to errors (pytest -W
                # error) — the counter above is the durable record;
                # never let the warning kill a worker thread.
                pass
        if hook is not None:
            try:
                hook(kind)
            except Exception:
                # The hook (flight recorder) is best-effort evidence
                # capture; it must never compound the original error.
                pass

    def record_flush(self, *, n_real: int, b_pad: int, bucket_m: int,
                     sum_m: int, solve_seconds: float,
                     reason: str, assemble_seconds: float = 0.0,
                     n_buckets: int = 1, launches: int = 1,
                     shards: tuple = (),
                     trace_id: Optional[str] = None) -> None:
        with self._lock:
            self.hists["solve_duration_seconds"].observe(
                solve_seconds, trace_id)
            self.hists["flush_duration_seconds"].observe(
                assemble_seconds + solve_seconds, trace_id)
            self.n_flushes += 1
            self.flush_reasons[reason] = (
                self.flush_reasons.get(reason, 0) + 1)
            self.launches += launches
            if n_buckets > 1:
                self.fused_flushes += 1
                self.fused_buckets += n_buckets
            for i, rows in enumerate(shards):
                while len(self.rows_by_device) <= i:
                    self.rows_by_device.append(0)
                self.rows_by_device[i] += int(rows)
            self.n_solved += n_real
            self.problems_real += n_real
            self.problems_padded += b_pad - n_real
            self.cells_valid += sum_m
            self.cells_total += b_pad * bucket_m
            self.solve_seconds += solve_seconds
            self.assemble_seconds += assemble_seconds
            self._t_last = time.perf_counter()
            if self._t0 is None:
                self._t0 = self._t_last

    @staticmethod
    def _percentile_of(xs: List[float], p: float) -> float:
        """Linear-interpolated percentile of a *sorted* sample list;
        0.0 when empty (finite Prometheus lines, never NaN)."""
        if not xs:
            return 0.0
        if len(xs) == 1:
            return xs[0]
        k = (p / 100.0) * (len(xs) - 1)
        lo = int(k)
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)

    def percentile(self, p: float) -> float:
        """Linear-interpolated percentile of recorded latencies,
        seconds.  An empty reservoir yields 0.0, not NaN — a fresh
        server's ``/metrics`` scrape must render finite Prometheus
        sample lines (Prometheus text parsers reject malformed values,
        and ``NaN`` percentiles poison alert rules)."""
        with self._lock:
            xs = sorted(self._latencies)
        return self._percentile_of(xs, p)

    def snapshot(self, cache_stats: Optional[Dict] = None) -> Dict:
        """One *consistent* summary dict: every field — the percentiles
        included — is computed from state copied under a single hold of
        the metrics lock.  (Percentiles used to be re-derived by two
        later ``percentile()`` calls, each re-acquiring the lock, so a
        scrape racing the completion worker could pair a pre-flush
        counter block with post-flush percentiles.)"""
        with self._lock:
            elapsed = ((self._t_last - self._t0)
                       if self._t0 is not None and self._t_last is not None
                       else 0.0)
            # Empty-state values are 0.0 (not NaN) so snapshot numbers
            # are always finite — see percentile().
            n_lat = len(self._latencies)
            mean = (sum(self._latencies) / n_lat) if n_lat else 0.0
            lat_sorted = sorted(self._latencies)
            prob_total = self.problems_real + self.problems_padded
            snap = {
                "n_solved": self.n_solved,
                "n_flushes": self.n_flushes,
                "flush_reasons": dict(self.flush_reasons),
                "launches_total": self.launches,
                "fused_flushes": self.fused_flushes,
                "fused_buckets": self.fused_buckets,
                "rows_per_device": list(self.rows_by_device),
                "elapsed_s": elapsed,
                "throughput_lps": (self.n_solved / elapsed
                                   if elapsed > 0 else 0.0),
                "latency_mean_ms": mean * 1e3,
                "latency_samples": n_lat,
                "latency_seen": self.lat_seen,
                "solve_seconds": self.solve_seconds,
                "assemble_seconds": self.assemble_seconds,
                "n_dispatched": self.n_dispatched,
                "inflight_now": self.inflight_now,
                "inflight_max": self.inflight_max,
                "overlapped_dispatches": self.overlapped_dispatches,
                "device_idle_s_est": self.device_idle_s,
                "errors": dict(self.errors),
                "padding_waste_problems": (
                    self.problems_padded / prob_total if prob_total
                    else 0.0),
                "padding_waste_cells": (
                    1.0 - self.cells_valid / self.cells_total
                    if self.cells_total else 0.0),
                "latency_p50_ms":
                    self._percentile_of(lat_sorted, 50.0) * 1e3,
                "latency_p99_ms":
                    self._percentile_of(lat_sorted, 99.0) * 1e3,
                "histograms": {name: h.state()
                               for name, h in self.hists.items()},
            }
        if cache_stats is not None:
            snap["cache"] = dict(cache_stats)
        return snap

    def format_report(self, cache_stats: Optional[Dict] = None) -> str:
        s = self.snapshot(cache_stats)
        sampled = (f" (reservoir: {s['latency_samples']} of "
                   f"{s['latency_seen']})"
                   if s["latency_seen"] > s["latency_samples"] else "")
        lines = [
            f"solved {s['n_solved']} LPs in {s['n_flushes']} flushes "
            f"over {s['elapsed_s']:.2f}s "
            f"({s['throughput_lps']:.1f} LPs/s)",
            f"latency ms: p50={s['latency_p50_ms']:.2f} "
            f"p99={s['latency_p99_ms']:.2f} "
            f"mean={s['latency_mean_ms']:.2f}" + sampled,
            f"padding waste: problems "
            f"{100 * s['padding_waste_problems']:.1f}%  cells "
            f"{100 * s['padding_waste_cells']:.1f}%",
            f"pipeline: {s['n_dispatched']} dispatched, max in flight "
            f"{s['inflight_max']}, overlapped "
            f"{s['overlapped_dispatches']}, device idle "
            f"~{s['device_idle_s_est']:.2f}s, assemble "
            f"{s['assemble_seconds']:.2f}s / solve "
            f"{s['solve_seconds']:.2f}s",
            "flushes by trigger: " + (", ".join(
                f"{k}={v}" for k, v in
                sorted(s['flush_reasons'].items())) or "none"),
            f"sharding: {s['launches_total']} launches / "
            f"{s['n_flushes']} flushes, fused {s['fused_flushes']} "
            f"units covering {s['fused_buckets']} buckets, rows/device "
            + (str(s["rows_per_device"]) if s["rows_per_device"]
               else "[]"),
        ]
        if s["errors"]:
            lines.append("errors: " + ", ".join(
                f"{k}={v}" for k, v in sorted(s["errors"].items())))
        if "cache" in s:
            c = s["cache"]
            lines.append(
                f"executable cache: {c['size']} built, {c['hits']} hits "
                f"/ {c['misses']} misses "
                f"({100 * c['hit_rate']:.1f}% hit rate)")
        return "\n".join(lines)
