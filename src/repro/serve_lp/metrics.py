"""Serving metrics: latency percentiles, throughput, padding waste.

Everything is recorded under one lock (submit, flush and timer threads
all write here) and summarised by :meth:`ServeMetrics.snapshot`.  Padding
waste is tracked two ways because they answer different questions:

* *problem* waste — neutral problems added to pad the batch dimension;
  these cost kernel time directly;
* *cell* waste — padded constraint rows (bucket_m - m per request) plus
  all cells of padding problems; this is the VMEM/bandwidth overhead of
  shape bucketing.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

_MAX_LATENCIES = 200_000  # reservoir cap; plenty for bench runs


class ServeMetrics:
    def __init__(self):
        self._lock = threading.Lock()
        self._latencies: List[float] = []
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self.n_solved = 0
        self.n_flushes = 0
        self.flush_reasons: Dict[str, int] = {}
        self.problems_real = 0
        self.problems_padded = 0
        self.cells_valid = 0
        self.cells_total = 0
        self.solve_seconds = 0.0

    def touch_clock(self) -> None:
        """Mark traffic activity (throughput is solved / active window)."""
        now = time.perf_counter()
        with self._lock:
            if self._t0 is None:
                self._t0 = now
            self._t_last = now

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            if len(self._latencies) < _MAX_LATENCIES:
                self._latencies.append(seconds)

    def record_flush(self, *, n_real: int, b_pad: int, bucket_m: int,
                     sum_m: int, solve_seconds: float,
                     reason: str) -> None:
        with self._lock:
            self.n_flushes += 1
            self.flush_reasons[reason] = (
                self.flush_reasons.get(reason, 0) + 1)
            self.n_solved += n_real
            self.problems_real += n_real
            self.problems_padded += b_pad - n_real
            self.cells_valid += sum_m
            self.cells_total += b_pad * bucket_m
            self.solve_seconds += solve_seconds
            self._t_last = time.perf_counter()
            if self._t0 is None:
                self._t0 = self._t_last

    def percentile(self, p: float) -> float:
        """Linear-interpolated percentile of recorded latencies, seconds."""
        with self._lock:
            xs = sorted(self._latencies)
        if not xs:
            return float("nan")
        if len(xs) == 1:
            return xs[0]
        k = (p / 100.0) * (len(xs) - 1)
        lo = int(k)
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)

    def snapshot(self, cache_stats: Optional[Dict] = None) -> Dict:
        with self._lock:
            elapsed = ((self._t_last - self._t0)
                       if self._t0 is not None and self._t_last is not None
                       else 0.0)
            n_lat = len(self._latencies)
            mean = (sum(self._latencies) / n_lat) if n_lat else float("nan")
            prob_total = self.problems_real + self.problems_padded
            snap = {
                "n_solved": self.n_solved,
                "n_flushes": self.n_flushes,
                "flush_reasons": dict(self.flush_reasons),
                "elapsed_s": elapsed,
                "throughput_lps": (self.n_solved / elapsed
                                   if elapsed > 0 else float("nan")),
                "latency_mean_ms": mean * 1e3,
                "solve_seconds": self.solve_seconds,
                "padding_waste_problems": (
                    self.problems_padded / prob_total if prob_total
                    else 0.0),
                "padding_waste_cells": (
                    1.0 - self.cells_valid / self.cells_total
                    if self.cells_total else 0.0),
            }
        snap["latency_p50_ms"] = self.percentile(50.0) * 1e3
        snap["latency_p99_ms"] = self.percentile(99.0) * 1e3
        if cache_stats is not None:
            snap["cache"] = dict(cache_stats)
        return snap

    def format_report(self, cache_stats: Optional[Dict] = None) -> str:
        s = self.snapshot(cache_stats)
        lines = [
            f"solved {s['n_solved']} LPs in {s['n_flushes']} flushes "
            f"over {s['elapsed_s']:.2f}s "
            f"({s['throughput_lps']:.1f} LPs/s)",
            f"latency ms: p50={s['latency_p50_ms']:.2f} "
            f"p99={s['latency_p99_ms']:.2f} "
            f"mean={s['latency_mean_ms']:.2f}",
            f"padding waste: problems "
            f"{100 * s['padding_waste_problems']:.1f}%  cells "
            f"{100 * s['padding_waste_cells']:.1f}%",
            "flushes by trigger: " + (", ".join(
                f"{k}={v}" for k, v in
                sorted(s['flush_reasons'].items())) or "none"),
        ]
        if "cache" in s:
            c = s["cache"]
            lines.append(
                f"executable cache: {c['size']} built, {c['hits']} hits "
                f"/ {c['misses']} misses "
                f"({100 * c['hit_rate']:.1f}% hit rate)")
        return "\n".join(lines)
