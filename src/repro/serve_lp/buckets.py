"""Shape bucketing and the executable cache.

Heterogeneous request streams would otherwise produce one XLA compile per
distinct ``(B, m)`` — the bucketing here rounds both dimensions up a small
geometric ladder so steady-state traffic lands on a bounded set of
executables:

* the constraint dimension ``m`` rounds up to ``base * 2^k`` — base is
  LANE (128) for the Pallas kernel, which needs a 128-lane multiple
  anyway, and 8 for the dense solvers, which have no layout requirement
  and should not pad an m=8 LP 16x (doubling bounds waste at 2x and
  caps the ladder at ~log2(m_max/base) rungs);
* the batch dimension rounds up to ``unit * 2^k`` where ``unit`` is
  one kernel ``tile`` under mesh sharding (the MeshLayout planner owns
  any further per-device padding) or ``tile * n_devices`` under the
  legacy pmap path (which needs whole equal shards); doubling again
  bounds the rung count.

The :class:`ExecutableCache` maps an :class:`ExecSpec` (the full shape +
method key) to a built solver executable and counts hits/misses so the
serving metrics can prove the bucketing works.  Since the serve loop
went pipelined, built entries are two-stage
:class:`~repro.serve_lp.sharding.Executable` objects (async ``dispatch``
returning device handles + blocking ``complete`` materializing host
numpy); plain synchronous callables are still accepted — the scheduler
adapts them via :func:`~repro.serve_lp.sharding.as_executable` — so
injected test builders keep working.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List

from repro.kernels.batch_lp import LANE
from repro.solver import SolverSpec
# One ladder implementation serves serving buckets *and* tuning-table
# shape classes — their alignment is what makes table lookups for a
# flush's bucket land on the entries the tuner recorded.
from repro.tune.table import bucket_pow2


def bucket_m(m: int, *, base: int = LANE) -> int:
    """Round a constraint count up to the geometric LANE ladder
    {base, 2*base, 4*base, ...}."""
    if m < 1:
        raise ValueError(f"m={m} < 1")
    return bucket_pow2(m, base)


def bucket_batch(batch: int, unit: int) -> int:
    """Round a flush size up to the geometric ladder of ``unit``
    multiples {unit, 2*unit, 4*unit, ...}."""
    if batch < 1:
        raise ValueError(f"batch={batch} < 1")
    return bucket_pow2(batch, unit)


def shape_ladder(m_max: int, *, base: int = LANE) -> List[int]:
    """All m-buckets needed to cover constraint counts up to ``m_max``."""
    out = [base]
    while out[-1] < m_max:
        out.append(out[-1] * 2)
    return out


# Flush-sharding modes a spec (and the scheduler) may name: "mesh" is
# the MeshLayout/shard_map planner, "pmap" the legacy even-split
# escape hatch (one release; see serve_lp.sharding).
SHARDING_MODES = ("mesh", "pmap")


@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """Everything that determines a compiled solver executable: the
    padded shapes, the device count, the sharding mode and the full
    (resolved) :class:`~repro.solver.SolverSpec`.

    Embedding the whole solver spec in the cache key is deliberate —
    two schedulers with different specs (dtype, shuffle seed, M, ...)
    can never alias each other's executables.  Likewise ``sharding``:
    a mesh executable and a pmap executable for the same shapes are
    different compiled plans and must not alias."""

    bucket_m: int      # padded constraint count (LANE multiple)
    b_pad: int         # padded batch size (see sharding-mode rules)
    solver: SolverSpec
    n_devices: int = 1
    sharding: str = "mesh"

    def __post_init__(self):
        if not isinstance(self.solver, SolverSpec):
            raise TypeError(
                f"solver must be a SolverSpec, got {type(self.solver)!r}")
        # Canonicalise so equal execution plans hash equal.
        object.__setattr__(self, "solver", self.solver.resolve())
        if self.solver.tile is None:
            raise ValueError(
                "ExecSpec needs a concrete solver.tile (shards are "
                "whole numbers of tiles)")
        if self.sharding not in SHARDING_MODES:
            raise ValueError(
                f"sharding={self.sharding!r} not in {SHARDING_MODES}")
        if self.bucket_m < 1:
            raise ValueError(f"bucket_m={self.bucket_m} < 1")
        if self.b_pad < 1:
            raise ValueError(f"b_pad={self.b_pad} < 1")
        # Only the Pallas kernel has a lane-layout requirement.
        if self.solver.backend == "kernel" and self.bucket_m % LANE:
            raise ValueError(f"bucket_m={self.bucket_m} not a {LANE} "
                             "multiple")
        # Only legacy pmap needs whole equal shards; the mesh planner
        # owns padding and accepts any positive b_pad.
        if (self.sharding == "pmap"
                and self.b_pad % (self.solver.tile * self.n_devices)):
            raise ValueError(
                f"b_pad={self.b_pad} not a multiple of tile*n_devices="
                f"{self.solver.tile * self.n_devices} (pmap needs "
                "whole equal shards; use sharding='mesh')")

    # Convenience views kept for call sites/reporting that predate the
    # embedded spec.
    @property
    def method(self) -> str:
        return self.solver.backend

    @property
    def tile(self) -> int:
        return self.solver.tile

    @property
    def chunk(self) -> int:
        return self.solver.chunk


class ExecutableCache:
    """spec -> built executable, with hit/miss accounting.

    ``builder`` is called under the cache lock on a miss; the returned
    executable (a dispatch/complete
    :class:`~repro.serve_lp.sharding.Executable` or any callable) is
    stored and reused for every later flush with the same spec.  (The
    first *invocation* still pays the XLA compile — the cache bounds
    how often that happens, it does not hide it.)  One cached
    executable may serve several concurrently in-flight flushes of the
    same spec: dispatch/complete hold no per-flush state, so that is
    safe by construction.
    """

    def __init__(self, builder: Callable[[ExecSpec], Callable]):
        self._builder = builder
        self._cache: Dict[ExecSpec, Callable] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, spec: ExecSpec) -> Callable:
        with self._lock:
            fn = self._cache.get(spec)
            if fn is not None:
                self.hits += 1
                return fn
            self.misses += 1
            fn = self._cache[spec] = self._builder(spec)
            return fn

    def __len__(self) -> int:
        return len(self._cache)

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "size": len(self._cache),
            "hit_rate": self.hits / total if total else 0.0,
        }

    def reset_stats(self) -> None:
        """Zero the hit/miss counters but keep built executables — used
        after a warmup pass so reports show steady-state behaviour."""
        with self._lock:
            self.hits = 0
            self.misses = 0

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self.hits = 0
            self.misses = 0
