"""Executable builder: one ExecSpec -> one dispatch/complete Executable.

A spec's ``sharding`` mode picks how the flushed super-batch spreads
over devices:

* ``"mesh"`` (default) — a :class:`~repro.serve_lp.mesh_layout.MeshLayout`
  plans per-device row counts (uneven shards allowed; unused devices
  get zero rows) and each :class:`~repro.serve_lp.mesh_layout.LaunchGroup`
  compiles to ``jax.jit(shard_map(solve))`` over a contiguous sub-mesh.
  The planner owns padding: ``b_pad`` only needs to be positive — rows
  are padded with neutral LPs up to whole kernel tiles here, never up
  to ``tile * n_devices`` blocks, so a prime-sized flush on 4 devices
  is legal.  A single local device compiles to plain ``jax.jit``
  (identical to the pre-mesh path).
* ``"pmap"`` — the legacy path, kept as a one-release escape hatch:
  ``jax.pmap`` splits the batch evenly over *all* devices and requires
  ``b_pad % (tile * n_devices) == 0``.  Tests assert the two paths are
  bit-identical; prefer ``"mesh"``.

Built executables are *two-stage* so the serve loop can pipeline:

* :meth:`Executable.dispatch` takes the scheduler's packed host buffers
  ``(L (B, 4, m), c (B, 2), mv (B, 1))`` already padded to the spec's
  shapes and returns an opaque handle (device arrays).  JAX dispatch is
  asynchronous, so the call returns while the solve is still in flight
  — nothing on this path materializes host numpy.
* :meth:`Executable.complete` blocks until the device is done and
  materializes host numpy ``(x (B, 2), feasible (B,) bool)`` — the
  scheduler's completion worker scatters those rows straight into
  per-request futures.

Calling the executable like a function composes the two stages
synchronously (the pre-pipelining contract; tests and one-off callers
use it).  On backends where XLA honours buffer donation (GPU/TPU) the
packed ``L`` block is donated, killing the device-side defensive copy
of the largest flush input; CPU ignores donation with a warning, so it
is gated off there.

The solve wraps the packed block in a
:class:`~repro.core.packed.PackedLPBatch` view (no repack) and runs the
same :func:`repro.solver.solve_with_spec` core as every other entry
point.  Because every problem row is independent, per-problem results
do not depend on which device solved them — sharding is pure layout.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.packed import PackedLPBatch
from repro.core.lp import PAD_B
from repro.obs.profiler import annotation as _device_annotation
from repro.serve_lp.buckets import ExecSpec
from repro.serve_lp.mesh_layout import (
    DATA_AXIS,
    MeshLayout,
    make_mesh,
    plan_layout,
)
from repro.solver import solve_with_spec

# Platforms where XLA actually honours input buffer donation; CPU
# ignores it (with a "donated buffers were not usable" warning), so
# donation is gated to keep test/CI logs clean.
_DONATING_PLATFORMS = ("gpu", "tpu", "cuda", "rocm")

# Opt-in per-launch jax.profiler.TraceAnnotation around each mesh
# launch-group dispatch, so device-profiler timelines carry the same
# launch labels as the host-side device.solve spans.  Off by default:
# the annotation context costs a little per launch and is only useful
# under an active profiler session.
_ANNOTATE_LAUNCHES = False


def set_launch_annotations(enabled: bool) -> None:
    """Enable/disable per-launch-group profiler annotations (the
    scheduler flips this on when its tracer was built with
    ``annotate_device=True``)."""
    global _ANNOTATE_LAUNCHES
    _ANNOTATE_LAUNCHES = bool(enabled)


def _make_solve(spec: ExecSpec) -> Callable:
    """The per-shard solve as a pure jax function of the packed arrays —
    the same :func:`repro.solver.solve_with_spec` core every other
    entry point runs through, so scheduler round-trips stay
    bit-identical to direct solves with the same spec."""

    def solve(L, c, mv):
        sol = solve_with_spec(spec.solver,
                              PackedLPBatch(L=L, c=c, m_valid=mv))
        return sol.x, sol.feasible

    return solve


class Executable:
    """A compiled flush solver split into dispatch and complete stages.

    ``dispatch(L, c, mv)`` enqueues the solve and returns an opaque
    handle without synchronizing; ``complete(handle)`` blocks until the
    device is done and returns host numpy ``(x, feasible)``.  The
    object is also callable — ``exe(L, c, mv)`` is the synchronous
    composition of the two stages.

    ``donated`` records whether the packed ``L`` input is donated to
    XLA (its device buffer is reused for outputs; the *host* buffer is
    unaffected and still owned by the flush-buffer pool until the
    flush completes).  ``layout`` is the :class:`MeshLayout` the
    executable was planned with (``None`` for legacy/injected
    executables); ``shards``/``n_launches`` expose the per-device row
    counts and device-launch count for metrics.
    """

    __slots__ = ("_dispatch", "_complete", "donated", "layout")

    def __init__(self, dispatch: Callable, complete: Callable, *,
                 donated: bool = False,
                 layout: Optional[MeshLayout] = None):
        self._dispatch = dispatch
        self._complete = complete
        self.donated = donated
        self.layout = layout

    @property
    def shards(self) -> Tuple[int, ...]:
        return self.layout.shards if self.layout is not None else ()

    @property
    def n_launches(self) -> int:
        return self.layout.n_launches if self.layout is not None else 1

    def dispatch(self, L, c, mv) -> Any:
        """Enqueue the solve; returns the in-flight result handle."""
        return self._dispatch(L, c, mv)

    def complete(self, handle) -> Tuple[np.ndarray, np.ndarray]:
        """Block until ``handle``'s solve finishes; host ``(x, feas)``."""
        return self._complete(handle)

    def __call__(self, L, c, mv) -> Tuple[np.ndarray, np.ndarray]:
        return self.complete(self.dispatch(L, c, mv))


def as_executable(fn) -> Executable:
    """Adapt a plain synchronous callable to the dispatch/complete
    protocol: its whole solve runs at dispatch time and ``complete`` is
    the identity.  Objects already exposing ``dispatch``/``complete``
    (built :class:`Executable`\\ s, test doubles) pass through unchanged,
    so injected caches keep working in the pipelined serve loop."""
    if hasattr(fn, "dispatch") and hasattr(fn, "complete"):
        return fn
    return Executable(fn, lambda handle: handle)


def _pad_rows(L, c, mv, b_pad: int):
    """Extend host buffers with neutral LPs (always-feasible, m_valid=0)
    up to ``b_pad`` rows — the planner-owned padding for flush sizes
    that are not whole-tile multiples."""
    n = b_pad - L.shape[0]
    if n <= 0:
        return L, c, mv
    Lp = np.zeros((n,) + L.shape[1:], dtype=L.dtype)
    Lp[:, 2, :] = PAD_B
    cp = np.zeros((n, 2), dtype=c.dtype)
    cp[:, 0] = 1.0
    mvp = np.zeros((n, 1), dtype=mv.dtype)
    return (np.concatenate([L, Lp]), np.concatenate([c, cp]),
            np.concatenate([mv, mvp]))


def _build_mesh_executable(spec: ExecSpec, devices, solve,
                           donate_kw) -> Executable:
    """Plan a :class:`MeshLayout` for the spec and compile one
    ``shard_map`` launch per :class:`LaunchGroup` (uneven layouts need
    at most two).  Each group jits over its own contiguous sub-mesh,
    so group launches land on disjoint devices and overlap."""
    layout = plan_layout(spec.b_pad, spec.tile, len(devices))

    launches = []
    for g in layout.groups:
        group_devs = devices[g.start:g.start + g.n_devices]
        if len(group_devs) == 1 and len(devices) == 1:
            # Single local device: plain jit, identical to the
            # pre-mesh path (no mesh machinery to pay for).
            fn = jax.jit(solve, **donate_kw)
        else:
            mesh = make_mesh(group_devs)
            # check_rep=False: every in/out is sharded over DATA_AXIS
            # (nothing replicated to check) and the pallas_call kernel
            # backend has no replication rule at all.
            fn = jax.jit(
                shard_map(
                    solve, mesh=mesh,
                    in_specs=(P(DATA_AXIS), P(DATA_AXIS), P(DATA_AXIS)),
                    out_specs=(P(DATA_AXIS), P(DATA_AXIS)),
                    check_rep=False),
                **donate_kw)
        launches.append((g.offset, g.rows, fn))

    b_pad = spec.b_pad
    labels = tuple(
        f"launch d{g.start}+{g.n_devices} rows{g.rows} m{spec.bucket_m}"
        for g in layout.groups)

    def dispatch(L, c, mv):
        if L.shape[0] != layout.b_pad:
            L, c, mv = _pad_rows(L, c, mv, layout.b_pad)
        if _ANNOTATE_LAUNCHES:
            out = []
            for (o, n, fn), label in zip(launches, labels):
                with _device_annotation(label):
                    out.append(fn(L[o:o + n], c[o:o + n], mv[o:o + n]))
            return tuple(out)
        return tuple(fn(L[o:o + n], c[o:o + n], mv[o:o + n])
                     for o, n, fn in launches)

    def complete(handles):
        xs = [np.asarray(h[0]) for h in handles]
        fs = [np.asarray(h[1]) for h in handles]
        x = xs[0] if len(xs) == 1 else np.concatenate(xs)
        feas = fs[0] if len(fs) == 1 else np.concatenate(fs)
        return x[:b_pad], feas[:b_pad]

    return Executable(dispatch, complete,
                      donated=bool(donate_kw), layout=layout)


def _build_pmap_executable(spec: ExecSpec, devices, solve,
                           donate_kw) -> Executable:
    """Legacy even-split path (``sharding="pmap"`` escape hatch)."""
    D = len(devices)
    if D == 1:
        jitted = jax.jit(solve, **donate_kw)

        def complete(handle):
            x, feas = handle
            return np.asarray(x), np.asarray(feas)

        return Executable(
            jitted, complete, donated=bool(donate_kw),
            layout=MeshLayout(shards=(spec.b_pad,), tile=spec.tile))

    pmapped = jax.pmap(solve, devices=devices, **donate_kw)
    per = spec.b_pad // D

    def shard(a):
        return a.reshape((D, per) + a.shape[1:])

    def dispatch(L, c, mv):
        return pmapped(shard(L), shard(c), shard(mv))

    def complete(handle):
        x, feas = handle
        return (np.asarray(x).reshape(spec.b_pad, 2),
                np.asarray(feas).reshape(spec.b_pad))

    return Executable(
        dispatch, complete, donated=bool(donate_kw),
        layout=MeshLayout(shards=(per,) * D, tile=spec.tile))


def build_executable(
    spec: ExecSpec,
    devices: Optional[Sequence[jax.Device]] = None,
) -> Executable:
    """Compile-on-first-call solver for one spec.  ``devices`` defaults
    to ``jax.devices()``."""
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) != spec.n_devices:
        raise ValueError(
            f"spec.n_devices={spec.n_devices} != len(devices)="
            f"{len(devices)}")
    solve = _make_solve(spec)
    donate = all(d.platform in _DONATING_PLATFORMS for d in devices)
    donate_kw = {"donate_argnums": (0,)} if donate else {}

    if spec.sharding == "pmap":
        return _build_pmap_executable(spec, devices, solve, donate_kw)
    return _build_mesh_executable(spec, devices, solve, donate_kw)
