"""Executable builder: one ExecSpec -> one dispatch/complete Executable.

Single device: a ``jax.jit`` closure over the spec.  Multiple devices:
``jax.pmap`` over the leading (device) axis — the flushed super-batch is
split evenly across ``jax.devices()`` along the batch dimension, each
shard solves independently (batch LP is embarrassingly parallel across
problems), and results gather back to host order.  The scheduler
guarantees ``b_pad % (tile * n_devices) == 0`` so every shard is a whole
number of kernel tiles.

Built executables are *two-stage* so the serve loop can pipeline:

* :meth:`Executable.dispatch` takes the scheduler's packed host buffers
  ``(L (B, 4, m), c (B, 2), mv (B, 1))`` already padded to the spec's
  shapes and returns an opaque handle (device arrays).  JAX dispatch is
  asynchronous, so the call returns while the solve is still in flight
  — nothing on this path materializes host numpy.
* :meth:`Executable.complete` blocks until the device is done and
  materializes host numpy ``(x (B, 2), feasible (B,) bool)`` — the
  scheduler's completion worker scatters those rows straight into
  per-request futures.

Calling the executable like a function composes the two stages
synchronously (the pre-pipelining contract; tests and one-off callers
use it).  On backends where XLA honours buffer donation (GPU/TPU) the
packed ``L`` block is donated, killing the device-side defensive copy
of the largest flush input; CPU ignores donation with a warning, so it
is gated off there.

The solve wraps the packed block in a
:class:`~repro.core.packed.PackedLPBatch` view (no repack) and runs the
same :func:`repro.solver.solve_with_spec` core as every other entry
point.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.packed import PackedLPBatch
from repro.serve_lp.buckets import ExecSpec
from repro.solver import solve_with_spec

# Platforms where XLA actually honours input buffer donation; CPU
# ignores it (with a "donated buffers were not usable" warning), so
# donation is gated to keep test/CI logs clean.
_DONATING_PLATFORMS = ("gpu", "tpu", "cuda", "rocm")


def _make_solve(spec: ExecSpec) -> Callable:
    """The per-shard solve as a pure jax function of the packed arrays —
    the same :func:`repro.solver.solve_with_spec` core every other
    entry point runs through, so scheduler round-trips stay
    bit-identical to direct solves with the same spec."""

    def solve(L, c, mv):
        sol = solve_with_spec(spec.solver,
                              PackedLPBatch(L=L, c=c, m_valid=mv))
        return sol.x, sol.feasible

    return solve


class Executable:
    """A compiled flush solver split into dispatch and complete stages.

    ``dispatch(L, c, mv)`` enqueues the solve and returns an opaque
    handle without synchronizing; ``complete(handle)`` blocks until the
    device is done and returns host numpy ``(x, feasible)``.  The
    object is also callable — ``exe(L, c, mv)`` is the synchronous
    composition of the two stages.

    ``donated`` records whether the packed ``L`` input is donated to
    XLA (its device buffer is reused for outputs; the *host* buffer is
    unaffected and still owned by the flush-buffer pool until the
    flush completes).
    """

    __slots__ = ("_dispatch", "_complete", "donated")

    def __init__(self, dispatch: Callable, complete: Callable, *,
                 donated: bool = False):
        self._dispatch = dispatch
        self._complete = complete
        self.donated = donated

    def dispatch(self, L, c, mv) -> Any:
        """Enqueue the solve; returns the in-flight result handle."""
        return self._dispatch(L, c, mv)

    def complete(self, handle) -> Tuple[np.ndarray, np.ndarray]:
        """Block until ``handle``'s solve finishes; host ``(x, feas)``."""
        return self._complete(handle)

    def __call__(self, L, c, mv) -> Tuple[np.ndarray, np.ndarray]:
        return self.complete(self.dispatch(L, c, mv))


def as_executable(fn) -> Executable:
    """Adapt a plain synchronous callable to the dispatch/complete
    protocol: its whole solve runs at dispatch time and ``complete`` is
    the identity.  Objects already exposing ``dispatch``/``complete``
    (built :class:`Executable`\\ s, test doubles) pass through unchanged,
    so injected caches keep working in the pipelined serve loop."""
    if hasattr(fn, "dispatch") and hasattr(fn, "complete"):
        return fn
    return Executable(fn, lambda handle: handle)


def build_executable(
    spec: ExecSpec,
    devices: Optional[Sequence[jax.Device]] = None,
) -> Executable:
    """Compile-on-first-call solver for one spec.  ``devices`` defaults
    to ``jax.devices()``; a single device falls back to plain jit."""
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) != spec.n_devices:
        raise ValueError(
            f"spec.n_devices={spec.n_devices} != len(devices)="
            f"{len(devices)}")
    solve = _make_solve(spec)
    D = spec.n_devices
    donate = all(d.platform in _DONATING_PLATFORMS for d in devices)
    donate_kw = {"donate_argnums": (0,)} if donate else {}

    if D == 1:
        jitted = jax.jit(solve, **donate_kw)

        def complete(handle):
            x, feas = handle
            return np.asarray(x), np.asarray(feas)

        return Executable(jitted, complete, donated=donate)

    pmapped = jax.pmap(solve, devices=devices, **donate_kw)
    per = spec.b_pad // D

    def shard(a):
        return a.reshape((D, per) + a.shape[1:])

    def dispatch(L, c, mv):
        return pmapped(shard(L), shard(c), shard(mv))

    def complete(handle):
        x, feas = handle
        return (np.asarray(x).reshape(spec.b_pad, 2),
                np.asarray(feas).reshape(spec.b_pad))

    return Executable(dispatch, complete, donated=donate)
