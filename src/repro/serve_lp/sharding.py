"""Executable builder: one ExecSpec -> one device-spanning solver fn.

Single device: a ``jax.jit`` closure over the spec.  Multiple devices:
``jax.pmap`` over the leading (device) axis — the flushed super-batch is
split evenly across ``jax.devices()`` along the batch dimension, each
shard solves independently (batch LP is embarrassingly parallel across
problems), and results gather back to host order.  The scheduler
guarantees ``b_pad % (tile * n_devices) == 0`` so every shard is a whole
number of kernel tiles.

The built callable takes the scheduler's packed host buffers
``(L (B, 4, m), c (B, 2), mv (B, 1))`` already padded to the spec's
shapes and returns numpy ``(x (B, 2), feasible (B,) bool)`` — host-side
because the scheduler scatters the rows straight into per-request
futures.  The packed block transfers and shards as one contiguous
array; the solve wraps it in a :class:`~repro.core.packed.PackedLPBatch`
view (no repack) and runs the same :func:`repro.solver.solve_with_spec`
core as every other entry point.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.packed import PackedLPBatch
from repro.serve_lp.buckets import ExecSpec
from repro.solver import solve_with_spec


def _make_solve(spec: ExecSpec) -> Callable:
    """The per-shard solve as a pure jax function of the packed arrays —
    the same :func:`repro.solver.solve_with_spec` core every other
    entry point runs through, so scheduler round-trips stay
    bit-identical to direct solves with the same spec."""

    def solve(L, c, mv):
        sol = solve_with_spec(spec.solver,
                              PackedLPBatch(L=L, c=c, m_valid=mv))
        return sol.x, sol.feasible

    return solve


def build_executable(
    spec: ExecSpec,
    devices: Optional[Sequence[jax.Device]] = None,
) -> Callable:
    """Compile-on-first-call solver for one spec.  ``devices`` defaults to
    ``jax.devices()``; a single device falls back to plain jit."""
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) != spec.n_devices:
        raise ValueError(
            f"spec.n_devices={spec.n_devices} != len(devices)="
            f"{len(devices)}")
    solve = _make_solve(spec)
    D = spec.n_devices

    if D == 1:
        jitted = jax.jit(solve)

        def run(L, c, mv):
            x, feas = jitted(L, c, mv)
            return np.asarray(x), np.asarray(feas)

        return run

    pmapped = jax.pmap(solve, devices=devices)
    per = spec.b_pad // D

    def shard(a):
        return a.reshape((D, per) + a.shape[1:])

    def run(L, c, mv):
        x, feas = pmapped(shard(L), shard(c), shard(mv))
        return (np.asarray(x).reshape(spec.b_pad, 2),
                np.asarray(feas).reshape(spec.b_pad))

    return run
