"""MeshLayout: how a flush's packed rows map onto a device mesh.

The serving layer packs every flush into SoA buffers ``(L (B, 4, m_pad),
c (B, 2), mv (B, 1))`` whose leading axis is *problems*.  Batch LP is
embarrassingly parallel across that axis, so sharding a flush is purely
a layout question: which contiguous row range does each device own?
This module answers it with a tiny size/stride layout algebra (in the
CuTe spirit: a layout is shapes + strides mapping logical coordinates
to offsets) instead of pmap's single implicit answer ("split evenly
over all local devices").

:func:`plan_layout` turns ``(rows, tile, n_devices)`` into a
:class:`MeshLayout`:

* **padding is owned here** — ``rows`` is rounded up to a whole number
  of kernel tiles (``b_pad``), never to a whole number of
  ``tile * n_devices`` blocks, so a prime-sized flush on 4 devices is
  legal and costs at most ``tile - 1`` pad rows;
* **shards may be uneven** — tile-units are dealt round-robin, so
  devices get ``q`` or ``q + 1`` tiles each and devices past the tile
  count get zero rows (an underfull flush simply doesn't use them);
* **launches are grouped** — consecutive devices with equal shard
  sizes form one :class:`LaunchGroup`, executed as a single
  ``shard_map`` over a contiguous sub-mesh.  The q/q+1 deal means a
  layout never needs more than two groups, so even a maximally uneven
  flush costs at most two launches (pmap would instead *pad* to the
  worst device).

Multi-host seam
---------------
Meshes built here are 1-D over the :data:`DATA_AXIS` ("data") axis of
local devices.  Multi-host serving slots in by (a) initialising the
runtime via ``jax.distributed.initialize`` — the entrypoint script
(``scripts/serve_entrypoint.sh`` / ``repro.serve_lp.rpc.__main__``)
already gates this on ``SERVE_COORDINATOR`` — and (b) prepending the
reserved :data:`HOST_AXIS` ("hosts") mesh axis so a layout becomes
``(hosts, data)`` with rows dealt to hosts first.  Nothing else in the
planner assumes a single host: shards are plain per-device row counts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

# Mesh axis names.  DATA_AXIS is the (only) axis current layouts shard
# over; HOST_AXIS is reserved for the documented multi-host extension.
DATA_AXIS = "data"
HOST_AXIS = "hosts"


@dataclasses.dataclass(frozen=True)
class LaunchGroup:
    """A contiguous run of devices with identical shard sizes — one
    ``shard_map`` launch over a sub-mesh.

    ``start`` is the first device index, ``n_devices`` the sub-mesh
    width, ``rows_per_device`` the (even, by construction) rows each
    member owns, and ``offset`` the global row offset of the group's
    slice ``[offset, offset + rows)``.
    """

    start: int
    n_devices: int
    rows_per_device: int
    offset: int

    @property
    def rows(self) -> int:
        return self.n_devices * self.rows_per_device

    @property
    def sizes(self) -> Tuple[int, int]:
        """Layout shape ``(device, row)`` of the group."""
        return (self.n_devices, self.rows_per_device)

    @property
    def strides(self) -> Tuple[int, int]:
        """Strides mapping a ``(device, row)`` coordinate to a global
        row: ``offset + d * rows_per_device + r``."""
        return (self.rows_per_device, 1)

    @property
    def device_indices(self) -> Tuple[int, ...]:
        """The global device indices this group's launch runs on —
        what a per-launch ``device.solve`` span reports as its device
        track membership."""
        return tuple(range(self.start, self.start + self.n_devices))


@dataclasses.dataclass(frozen=True)
class MeshLayout:
    """Per-device row counts for one flush, plus the derived launch
    plan.  ``shards[i]`` is the number of packed rows device ``i``
    owns; zeros are legal (the device takes no part in the flush).
    Every shard is a whole number of ``tile``-row kernel tiles.
    """

    shards: Tuple[int, ...]
    tile: int

    def __post_init__(self):
        if self.tile < 1:
            raise ValueError(f"tile={self.tile} < 1")
        if not self.shards:
            raise ValueError("layout needs at least one device")
        for i, s in enumerate(self.shards):
            if s < 0 or s % self.tile:
                raise ValueError(
                    f"shard[{i}]={s} is not a non-negative multiple of "
                    f"tile={self.tile}")
        if sum(self.shards) < 1:
            raise ValueError("layout carries zero rows")

    @property
    def b_pad(self) -> int:
        """Total padded rows the layout carries."""
        return sum(self.shards)

    @property
    def n_devices(self) -> int:
        return len(self.shards)

    @property
    def used_devices(self) -> int:
        return sum(1 for s in self.shards if s)

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Global row offset of each device's slice (exclusive scan)."""
        out, acc = [], 0
        for s in self.shards:
            out.append(acc)
            acc += s
        return tuple(out)

    @property
    def groups(self) -> Tuple[LaunchGroup, ...]:
        """Consecutive equal-sized non-empty shards, merged: the
        launch plan.  The q/q+1 deal in :func:`plan_layout` guarantees
        at most two groups."""
        groups: List[LaunchGroup] = []
        offsets = self.offsets
        i = 0
        while i < len(self.shards):
            s = self.shards[i]
            if s == 0:
                i += 1
                continue
            j = i
            while j + 1 < len(self.shards) and self.shards[j + 1] == s:
                j += 1
            groups.append(LaunchGroup(
                start=i, n_devices=j - i + 1, rows_per_device=s,
                offset=offsets[i]))
            i = j + 1
        return tuple(groups)

    @property
    def n_launches(self) -> int:
        return len(self.groups)

    def global_row(self, device: int, local_row: int) -> int:
        """Apply the layout: map a ``(device, local_row)`` coordinate
        to the global packed-row index."""
        if not 0 <= device < len(self.shards):
            raise IndexError(f"device {device} out of range")
        if not 0 <= local_row < self.shards[device]:
            raise IndexError(
                f"row {local_row} out of range for shard of "
                f"{self.shards[device]}")
        return self.offsets[device] + local_row

    def pad_rows(self, rows: int) -> int:
        """Pad rows the layout adds on top of ``rows`` real rows."""
        return self.b_pad - rows

    def describe(self) -> str:
        """One-line human layout, e.g. ``64 rows = [16 16 16 16] @
        tile=16, 1 launch``."""
        shard_s = " ".join(str(s) for s in self.shards)
        n = self.n_launches
        return (f"{self.b_pad} rows = [{shard_s}] @ tile={self.tile}, "
                f"{n} launch{'es' if n != 1 else ''}")


def plan_layout(rows: int, tile: int, n_devices: int) -> MeshLayout:
    """Plan how ``rows`` packed problems (real + any bucket padding the
    caller already applied) spread over ``n_devices`` devices.

    The planner owns padding: ``rows`` is rounded up to whole
    ``tile``-row units — *not* to ``tile * n_devices`` — then the tile
    units are dealt over ``min(n_devices, n_tiles)`` devices as ``q``
    or ``q + 1`` tiles each (larger shards first, so group boundaries
    are contiguous).  Devices beyond the tile count get zero rows.
    """
    if rows < 1:
        raise ValueError(f"rows={rows} < 1")
    if tile < 1:
        raise ValueError(f"tile={tile} < 1")
    if n_devices < 1:
        raise ValueError(f"n_devices={n_devices} < 1")
    n_tiles = -(-rows // tile)
    k = min(n_devices, n_tiles)
    q, r = divmod(n_tiles, k)
    shards = tuple(
        ((q + 1) * tile if i < r else q * tile) if i < k else 0
        for i in range(n_devices))
    return MeshLayout(shards=shards, tile=tile)


def make_mesh(devices: Sequence, axis: str = DATA_AXIS):
    """A 1-D :class:`jax.sharding.Mesh` over ``devices``.  Multi-host
    layouts will prepend :data:`HOST_AXIS`; see the module docstring."""
    import jax

    return jax.sharding.Mesh(np.asarray(devices), (axis,))
