"""Admission control: everything that happens to a request *before* it
may touch the scheduler.

The layers run in a fixed order, cheapest first, and every rejection is
a typed 4xx (:class:`RpcError` carries the HTTP status, a stable
machine-readable ``code``, and — for retryable rejections — a
``Retry-After`` hint):

1. **validation** — the JSON body is parsed into ``(A (m,2), b (m,),
   c (2,))`` problems with shape/dtype/m-bounds/finiteness checked
   eagerly (400/413/422 before any scheduler state is touched);
2. **deadline** — requests carry a latency budget (``X-Deadline-Ms``
   header or ``deadline_ms`` body field); one that arrives already
   expired is rejected with 504 instead of solved, and the server
   cancels still-queued work when the budget runs out mid-flight;
3. **quota** — per-tenant token buckets (:mod:`.quota`), 429 +
   ``Retry-After`` on exhaustion;
4. **backpressure** — load is shed with 429 when the scheduler is
   demonstrably behind: the in-flight flush depth has hit the PR 6
   ``max_inflight`` backpressure bound *and* the submit queues are deep,
   or the oldest queued request has aged past ``max_queue_age_s``
   (flushes not keeping up with arrivals).  Shedding keeps the queue
   bounded — overload turns into fast 429s, never an unbounded queue.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

DEADLINE_HEADER = "x-deadline-ms"
TENANT_HEADER = "x-tenant"


class RpcError(Exception):
    """A typed request rejection: HTTP status + stable error code.

    ``retry_after_s`` (when set) becomes a ``Retry-After`` response
    header — present on retryable 429s, absent on malformed-request
    4xxs that retrying cannot fix.
    """

    def __init__(self, status: int, code: str, message: str,
                 retry_after_s: Optional[float] = None):
        super().__init__(message)
        self.status = int(status)
        self.code = str(code)
        self.message = str(message)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Bounds the admission layer enforces before the scheduler."""

    m_max: int = 4096             # per-problem constraint-count cap
    batch_max: int = 1024         # LPs per request cap
    body_max_bytes: int = 8 << 20
    max_pending: int = 4096       # shed when queues this deep and
                                  # in-flight depth is at its bound
    max_queue_age_s: float = 0.5  # shed when the oldest queued request
                                  # has waited this long
    shed_retry_after_s: float = 0.05
    default_deadline_s: Optional[float] = None  # None = no deadline

    def __post_init__(self):
        if self.m_max < 1:
            raise ValueError(f"m_max={self.m_max} < 1")
        if self.batch_max < 1:
            raise ValueError(f"batch_max={self.batch_max} < 1")


# -- validation ------------------------------------------------------------

Problem = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _as_problem(obj: Any, dtype: np.dtype, policy: AdmissionPolicy,
                where: str) -> Problem:
    if not isinstance(obj, dict):
        raise RpcError(422, "bad_problem",
                       f"{where}: each problem must be an object with "
                       "A, b, c")
    missing = [k for k in ("A", "b", "c") if k not in obj]
    if missing:
        raise RpcError(422, "missing_field",
                       f"{where}: missing {', '.join(missing)}")
    try:
        A = np.asarray(obj["A"], dtype)
        b = np.asarray(obj["b"], dtype)
        c = np.asarray(obj["c"], dtype)
    except (TypeError, ValueError) as e:
        raise RpcError(422, "bad_dtype",
                       f"{where}: A/b/c must be numeric arrays ({e})")
    if A.ndim != 2 or A.shape[1] != 2:
        raise RpcError(422, "bad_shape",
                       f"{where}: A must be (m, 2), got {A.shape}")
    m = A.shape[0]
    if m < 1:
        raise RpcError(422, "m_out_of_bounds",
                       f"{where}: need at least 1 constraint")
    if m > policy.m_max:
        raise RpcError(422, "m_out_of_bounds",
                       f"{where}: m={m} exceeds the server bound "
                       f"m_max={policy.m_max}")
    if b.shape != (m,):
        raise RpcError(422, "bad_shape",
                       f"{where}: b must be ({m},) to match A, got "
                       f"{b.shape}")
    if c.shape != (2,):
        raise RpcError(422, "bad_shape",
                       f"{where}: c must be (2,), got {c.shape}")
    if not (np.isfinite(A).all() and np.isfinite(b).all()
            and np.isfinite(c).all()):
        raise RpcError(422, "nonfinite",
                       f"{where}: A/b/c must be finite (no NaN/inf)")
    return A, b, c


def parse_solve_payload(body: bytes, dtype: np.dtype,
                        policy: AdmissionPolicy
                        ) -> Tuple[List[Problem], bool]:
    """Parse a ``POST /v1/solve`` body into validated problems.

    Accepts the single form ``{"A": ..., "b": ..., "c": ...}`` and the
    batch form ``{"problems": [{...}, ...]}``.  Returns ``(problems,
    is_batch)``; every rejection is a typed :class:`RpcError` raised
    before any scheduler state is touched.
    """
    if len(body) > policy.body_max_bytes:
        raise RpcError(413, "body_too_large",
                       f"request body {len(body)}B exceeds "
                       f"{policy.body_max_bytes}B")
    try:
        payload = json.loads(body)
    except (ValueError, UnicodeDecodeError) as e:
        raise RpcError(400, "bad_json", f"request body is not JSON ({e})")
    if not isinstance(payload, dict):
        raise RpcError(400, "bad_request",
                       "request body must be a JSON object")
    if "problems" in payload:
        probs = payload["problems"]
        if not isinstance(probs, list) or not probs:
            raise RpcError(422, "bad_request",
                           "problems must be a non-empty array")
        if len(probs) > policy.batch_max:
            raise RpcError(413, "batch_too_large",
                           f"{len(probs)} problems exceeds the server "
                           f"bound batch_max={policy.batch_max}")
        return ([_as_problem(p, dtype, policy, f"problems[{i}]")
                 for i, p in enumerate(probs)], True)
    return [_as_problem(payload, dtype, policy, "body")], False


# -- deadlines -------------------------------------------------------------

def deadline_budget_s(headers: Dict[str, str], payload_deadline_ms: Any,
                      policy: AdmissionPolicy) -> Optional[float]:
    """The request's latency budget in seconds (relative — a budget,
    not a wall-clock instant, so client/server clock skew is
    irrelevant).  Header wins over body field wins over the policy
    default; ``None`` means no deadline."""
    raw = headers.get(DEADLINE_HEADER, payload_deadline_ms)
    if raw is None:
        return policy.default_deadline_s
    try:
        ms = float(raw)
    except (TypeError, ValueError):
        raise RpcError(400, "bad_deadline",
                       f"deadline must be a number of milliseconds, "
                       f"got {raw!r}")
    if not math.isfinite(ms) or ms <= 0.0:
        raise RpcError(400, "bad_deadline",
                       f"deadline_ms={ms} must be finite and > 0")
    return ms / 1e3


# -- backpressure ----------------------------------------------------------

def check_backpressure(scheduler, policy: AdmissionPolicy,
                       now: Optional[float] = None) -> None:
    """Shed load (429) when the scheduler is measurably behind.

    Two independent signals, either sheds:

    * *depth*: the in-flight flush gauge has hit the scheduler's
      ``max_inflight`` backpressure bound (dispatch would block) **and**
      the submit queues already hold ``max_pending`` requests — the
      device is saturated and a backlog is forming;
    * *age*: the oldest queued request has waited longer than
      ``max_queue_age_s`` — flushes are not keeping up with arrivals,
      so admitting more work can only grow the queue.
    """
    pending = scheduler.pending()
    if (pending >= policy.max_pending
            and scheduler.inflight >= scheduler.max_inflight):
        raise RpcError(
            429, "overloaded",
            f"server overloaded: {pending} LPs queued with the "
            f"in-flight flush depth at its bound "
            f"({scheduler.max_inflight})",
            retry_after_s=policy.shed_retry_after_s)
    age = scheduler.queue_age_s(now if now is not None
                                else time.perf_counter())
    if age > policy.max_queue_age_s:
        raise RpcError(
            429, "overloaded",
            f"server overloaded: oldest queued request has waited "
            f"{age * 1e3:.0f}ms (> {policy.max_queue_age_s * 1e3:.0f}ms)",
            retry_after_s=policy.shed_retry_after_s)
