"""Prometheus text exposition (version 0.0.4) for the RPC server.

Renders the scheduler's :class:`~repro.serve_lp.metrics.ServeMetrics`
snapshot plus the RPC layer's own counters as a ``GET /metrics``
scrape.  No client library: the text format is a few lines of
``# HELP`` / ``# TYPE`` plus ``name{labels} value`` samples, and
growing a dependency for that would violate the no-new-deps rule.

Two format obligations are enforced here:

* every sample value is rendered finite — Prometheus rejects sample
  lines it cannot parse, and one malformed line poisons the whole
  scrape, so non-finite values are coerced to 0 (the metrics layer
  already guards its empty-reservoir cases; this is the belt to that
  suspenders);
* label values are escaped per the exposition spec (backslash, quote,
  newline).

Histograms: the four duration families recorded by ``ServeMetrics``
(request latency, queue wait, solve, flush) render in the real
Prometheus histogram representation — cumulative ``_bucket{le=...}``
lines, ``_sum`` and ``_count`` — instead of only percentile gauges, so
scrapes can be aggregated across servers and over time.  The latency
families additionally carry OpenMetrics-style *exemplars*
(``... # {trace_id="..."} value``) naming the last trace id observed
in each bucket: a dashboard's p99 spike links straight to a pullable
``/debug/trace?trace_id=``.  (Exposition 0.0.4 parsers that predate
exemplars simply treat the `` # {...}`` suffix as one more value
token; Prometheus itself has parsed the form since 2.26.)
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

# Human blurbs for the histogram families exported by ServeMetrics.
_HIST_HELP = {
    "request_latency_seconds":
        "Submit-to-result latency per request (histogram)",
    "queue_wait_seconds":
        "Submit-to-flush-assembly queue wait per request (histogram)",
    "solve_duration_seconds":
        "Dispatch-to-complete device service time per flush "
        "(histogram)",
    "flush_duration_seconds":
        "Assembly-start-to-complete duration per flush (histogram)",
}

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _finite(v) -> float:
    try:
        f = float(v)
    except (TypeError, ValueError):
        return 0.0
    return f if math.isfinite(f) else 0.0


def _escape(label: str) -> str:
    return (str(label).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


class _Writer:
    def __init__(self, prefix: str):
        self.prefix = prefix
        self.lines: List[str] = []

    def family(self, name: str, kind: str, help_: str,
               samples: List[Tuple[Dict[str, str], float]]) -> None:
        """One metric family: HELP/TYPE then its samples."""
        full = f"{self.prefix}_{name}"
        self.lines.append(f"# HELP {full} {help_}")
        self.lines.append(f"# TYPE {full} {kind}")
        for labels, value in samples:
            lab = ("{" + ",".join(
                f'{k}="{_escape(v)}"' for k, v in sorted(labels.items()))
                + "}") if labels else ""
            self.lines.append(f"{full}{lab} {_finite(value)}")

    def scalar(self, name: str, kind: str, help_: str, value) -> None:
        self.family(name, kind, help_, [({}, value)])

    def histogram(self, name: str, help_: str, state: Dict,
                  exemplars: bool = True) -> None:
        """One histogram family from a ``_Histogram.state()`` dict:
        cumulative ``_bucket{le=...}`` lines (exemplar-suffixed where
        one was captured), then ``_sum`` and ``_count``."""
        full = f"{self.prefix}_{name}"
        self.lines.append(f"# HELP {full} {help_}")
        self.lines.append(f"# TYPE {full} histogram")
        bounds = state["bounds"]
        cum = state["cumulative"]
        ex = state.get("exemplars") or {}
        for i, b in enumerate(bounds):
            le = f"{float(b):.12g}"
            line = f'{full}_bucket{{le="{le}"}} {int(cum[i])}'
            e = ex.get(i, ex.get(str(i)))
            if exemplars and e:
                line += (f' # {{trace_id="{_escape(e[1])}"}} '
                         f'{_finite(e[0])}')
            self.lines.append(line)
        line = f'{full}_bucket{{le="+Inf"}} {int(cum[-1])}'
        e = ex.get(len(bounds), ex.get(str(len(bounds))))
        if exemplars and e:
            line += f' # {{trace_id="{_escape(e[1])}"}} {_finite(e[0])}'
        self.lines.append(line)
        self.lines.append(f"{full}_sum {_finite(state['sum'])}")
        self.lines.append(f"{full}_count {int(state['count'])}")

    def render(self) -> str:
        return "\n".join(self.lines) + "\n"


def render_metrics(snapshot: Dict, *,
                   rpc: Optional[Dict] = None,
                   quotas: Optional[Dict] = None,
                   slo: Optional[Dict] = None,
                   trace: Optional[Dict] = None,
                   prefix: str = "repro_serve") -> str:
    """The full scrape body: scheduler snapshot + RPC counters.

    ``snapshot`` is ``ServeMetrics.snapshot(cache_stats)``; ``rpc`` is
    :meth:`~repro.serve_lp.rpc.server.RpcCounters.snapshot`; ``quotas``
    is :meth:`~repro.serve_lp.rpc.quota.QuotaManager.snapshot`;
    ``slo`` is :meth:`~repro.serve_lp.rpc.slo.SLOController.plans`
    (``{bucket_m: BucketPlan}``); ``trace`` is ``Tracer.stats()``.
    """
    w = _Writer(prefix)

    # -- scheduler/solver plane ------------------------------------------
    w.scalar("solved_total", "counter",
             "LPs solved through the scheduler", snapshot["n_solved"])
    w.family("flushes_total", "counter",
             "Scheduler flushes by trigger reason",
             [({"reason": r}, v)
              for r, v in sorted(snapshot["flush_reasons"].items())]
             or [({}, 0)])
    w.scalar("dispatched_total", "counter",
             "Flushes dispatched to the device",
             snapshot["n_dispatched"])
    w.scalar("inflight_flushes", "gauge",
             "Flushes currently dispatched and not completed",
             snapshot["inflight_now"])
    w.scalar("inflight_flushes_max", "gauge",
             "High-watermark of concurrently in-flight flushes",
             snapshot["inflight_max"])
    w.scalar("overlapped_dispatches_total", "counter",
             "Dispatches that found the device already busy",
             snapshot["overlapped_dispatches"])
    w.scalar("device_idle_seconds_total", "counter",
             "Estimated seconds the device sat idle between flushes",
             snapshot["device_idle_s_est"])
    w.scalar("solve_seconds_total", "counter",
             "Cumulative dispatch-to-complete device service time",
             snapshot["solve_seconds"])
    w.scalar("assemble_seconds_total", "counter",
             "Cumulative host-side flush assembly time",
             snapshot["assemble_seconds"])
    w.scalar("throughput_lps", "gauge",
             "Solved LPs per second over the active traffic window",
             snapshot["throughput_lps"])
    w.family("latency_seconds", "summary",
             "End-to-end submit-to-result latency (reservoir-sampled)",
             [({"quantile": "0.5"}, snapshot["latency_p50_ms"] / 1e3),
              ({"quantile": "0.99"}, snapshot["latency_p99_ms"] / 1e3)])
    w.scalar("latency_seconds_count", "counter",
             "Latency samples offered to the reservoir",
             snapshot["latency_seen"])
    for name, state in sorted(
            (snapshot.get("histograms") or {}).items()):
        w.histogram(name, _HIST_HELP.get(name, name), state)
    w.scalar("launches_total", "counter",
             "Device launches issued (a mesh flush may group into "
             "1-2 sub-mesh launches)",
             snapshot.get("launches_total", 0))
    w.scalar("fused_flushes_total", "counter",
             "Fused multi-bucket flush units dispatched",
             snapshot.get("fused_flushes", 0))
    w.scalar("fused_buckets_total", "counter",
             "m-buckets folded into fused flush units",
             snapshot.get("fused_buckets", 0))
    w.family("device_rows_total", "counter",
             "Packed problem rows dispatched per device index",
             [({"device": str(i)}, v) for i, v in
              enumerate(snapshot.get("rows_per_device", []))]
             or [({}, 0)])
    w.scalar("padding_waste_problems_ratio", "gauge",
             "Fraction of solved problem slots that were padding",
             snapshot["padding_waste_problems"])
    w.scalar("padding_waste_cells_ratio", "gauge",
             "Fraction of solved constraint cells that were padding",
             snapshot["padding_waste_cells"])
    w.family("errors_total", "counter",
             "Scheduler-side errors by kind",
             [({"kind": k}, v)
              for k, v in sorted(snapshot["errors"].items())]
             or [({}, 0)])
    cache = snapshot.get("cache")
    if cache is not None:
        w.scalar("executables_built", "gauge",
                 "Distinct compiled flush executables", cache["size"])
        w.scalar("executable_cache_hits_total", "counter",
                 "Executable cache hits", cache["hits"])
        w.scalar("executable_cache_misses_total", "counter",
                 "Executable cache misses", cache["misses"])

    # -- RPC plane --------------------------------------------------------
    if rpc is not None:
        w.family("rpc_requests_total", "counter",
                 "HTTP requests by endpoint and status code",
                 [({"endpoint": e, "code": str(c)}, v)
                  for (e, c), v in sorted(rpc["requests"].items())]
                 or [({}, 0)])
        w.family("rpc_shed_total", "counter",
                 "Requests shed before solving, by reason",
                 [({"reason": r}, v)
                  for r, v in sorted(rpc["shed"].items())]
                 or [({}, 0)])
        w.scalar("rpc_inprogress", "gauge",
                 "Solve requests currently being handled",
                 rpc["inprogress"])
        w.scalar("rpc_lps_accepted_total", "counter",
                 "LPs admitted past admission control",
                 rpc["lps_accepted"])
    # -- SLO plane: the controller's installed per-bucket plans ----------
    if slo is not None:
        plans = sorted(slo.items())
        w.family("slo_bucket_max_batch", "gauge",
                 "SLO-planned size trigger per m-bucket",
                 [({"bucket_m": str(bm), "source": p.source},
                   p.max_batch) for bm, p in plans] or [({}, 0)])
        w.family("slo_bucket_max_wait_seconds", "gauge",
                 "SLO-planned wait trigger per m-bucket",
                 [({"bucket_m": str(bm), "source": p.source},
                   p.max_wait_s) for bm, p in plans] or [({}, 0)])
        w.family("slo_bucket_est_flush_seconds", "gauge",
                 "Estimated flush service time per m-bucket (0 when "
                 "no measured tuning entry)",
                 [({"bucket_m": str(bm), "source": p.source},
                   p.est_flush_s or 0.0) for bm, p in plans]
                 or [({}, 0)])
        w.family("slo_bucket_allow_fuse", "gauge",
                 "Fused-flush policy per m-bucket (1 = may join "
                 "cross-bucket fused flush units)",
                 [({"bucket_m": str(bm), "source": p.source},
                   1 if p.allow_fuse else 0) for bm, p in plans]
                 or [({}, 0)])
    # -- trace plane: the span ring's own health -------------------------
    if trace is not None:
        w.scalar("trace_enabled", "gauge",
                 "Whether the serving stack records spans",
                 trace.get("enabled", 0))
        w.scalar("trace_spans_recorded_total", "counter",
                 "Ended spans committed to the ring",
                 trace.get("spans_recorded", 0))
        w.scalar("trace_spans_dropped_total", "counter",
                 "Spans the bounded ring has already forgotten",
                 trace.get("ring_dropped", 0))
        w.scalar("trace_ring_len", "gauge",
                 "Spans currently resident in the ring",
                 trace.get("ring_len", 0))
    if quotas is not None:
        w.family("rpc_quota_admitted_total", "counter",
                 "LPs admitted by the per-tenant token bucket",
                 [({"tenant": t}, q["admitted"])
                  for t, q in sorted(quotas.items())] or [({}, 0)])
        w.family("rpc_quota_rejected_total", "counter",
                 "LPs rejected by the per-tenant token bucket",
                 [({"tenant": t}, q["rejected"])
                  for t, q in sorted(quotas.items())] or [({}, 0)])
        w.family("rpc_quota_tokens", "gauge",
                 "Tokens currently available per tenant",
                 [({"tenant": t}, q["tokens"])
                  for t, q in sorted(quotas.items())] or [({}, 0)])
    return w.render()


def validate_exposition(text: str) -> None:
    """Structural check of an exposition body (used by tests and the
    bench): every non-comment line is ``name{labels} value`` with a
    finite float value, optionally followed by an OpenMetrics exemplar
    (`` # {labels} value``); and every family declared ``# TYPE ...
    histogram`` obeys the histogram grammar — cumulative
    non-decreasing ``_bucket`` counts with ``le`` labels, a terminal
    ``le="+Inf"`` bucket, and ``_sum``/``_count`` lines with ``_count``
    equal to the +Inf bucket.  Raises ValueError on any violation."""
    hists: Dict[str, Dict] = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) >= 4 and parts[3] == "histogram":
                hists[parts[2]] = {"last": None, "inf": None,
                                   "sum": False, "count": None}
            continue
        if line.startswith("#"):
            continue
        sample, sep, exemplar = line.partition(" # ")
        try:
            metric, value = sample.rsplit(" ", 1)
            v = float(value)
        except ValueError:
            raise ValueError(f"malformed sample line: {line!r}")
        if not math.isfinite(v):
            raise ValueError(f"non-finite sample value: {line!r}")
        if sep:
            ex = exemplar.strip()
            head, brace, tail = ex.partition("}")
            bad = (not ex.startswith("{") or not brace
                   or not tail.strip())
            if not bad:
                try:
                    ev = float(tail.strip().split()[0])
                    bad = not math.isfinite(ev)
                except ValueError:
                    bad = True
            if bad:
                raise ValueError(f"malformed exemplar: {line!r}")
        name = metric.split("{", 1)[0]
        for base, st in hists.items():
            if name == f"{base}_bucket":
                if 'le="' not in metric:
                    raise ValueError(
                        f"histogram bucket without le label: {line!r}")
                if st["last"] is not None and v < st["last"]:
                    raise ValueError(
                        f"non-cumulative histogram buckets: {line!r}")
                st["last"] = v
                if 'le="+Inf"' in metric:
                    st["inf"] = v
            elif name == f"{base}_sum":
                st["sum"] = True
            elif name == f"{base}_count":
                st["count"] = v
    for base, st in hists.items():
        if st["inf"] is None:
            raise ValueError(f"histogram {base} has no +Inf bucket")
        if not st["sum"]:
            raise ValueError(f"histogram {base} has no _sum line")
        if st["count"] is None:
            raise ValueError(f"histogram {base} has no _count line")
        if st["count"] != st["inf"]:
            raise ValueError(
                f"histogram {base}: _count {st['count']} != +Inf "
                f"bucket {st['inf']}")
