"""Prometheus text exposition (version 0.0.4) for the RPC server.

Renders the scheduler's :class:`~repro.serve_lp.metrics.ServeMetrics`
snapshot plus the RPC layer's own counters as a ``GET /metrics``
scrape.  No client library: the text format is a few lines of
``# HELP`` / ``# TYPE`` plus ``name{labels} value`` samples, and
growing a dependency for that would violate the no-new-deps rule.

Two format obligations are enforced here:

* every sample value is rendered finite — Prometheus rejects sample
  lines it cannot parse, and one malformed line poisons the whole
  scrape, so non-finite values are coerced to 0 (the metrics layer
  already guards its empty-reservoir cases; this is the belt to that
  suspenders);
* label values are escaped per the exposition spec (backslash, quote,
  newline).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _finite(v) -> float:
    try:
        f = float(v)
    except (TypeError, ValueError):
        return 0.0
    return f if math.isfinite(f) else 0.0


def _escape(label: str) -> str:
    return (str(label).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


class _Writer:
    def __init__(self, prefix: str):
        self.prefix = prefix
        self.lines: List[str] = []

    def family(self, name: str, kind: str, help_: str,
               samples: List[Tuple[Dict[str, str], float]]) -> None:
        """One metric family: HELP/TYPE then its samples."""
        full = f"{self.prefix}_{name}"
        self.lines.append(f"# HELP {full} {help_}")
        self.lines.append(f"# TYPE {full} {kind}")
        for labels, value in samples:
            lab = ("{" + ",".join(
                f'{k}="{_escape(v)}"' for k, v in sorted(labels.items()))
                + "}") if labels else ""
            self.lines.append(f"{full}{lab} {_finite(value)}")

    def scalar(self, name: str, kind: str, help_: str, value) -> None:
        self.family(name, kind, help_, [({}, value)])

    def render(self) -> str:
        return "\n".join(self.lines) + "\n"


def render_metrics(snapshot: Dict, *,
                   rpc: Optional[Dict] = None,
                   quotas: Optional[Dict] = None,
                   prefix: str = "repro_serve") -> str:
    """The full scrape body: scheduler snapshot + RPC counters.

    ``snapshot`` is ``ServeMetrics.snapshot(cache_stats)``; ``rpc`` is
    :meth:`~repro.serve_lp.rpc.server.RpcCounters.snapshot`; ``quotas``
    is :meth:`~repro.serve_lp.rpc.quota.QuotaManager.snapshot`.
    """
    w = _Writer(prefix)

    # -- scheduler/solver plane ------------------------------------------
    w.scalar("solved_total", "counter",
             "LPs solved through the scheduler", snapshot["n_solved"])
    w.family("flushes_total", "counter",
             "Scheduler flushes by trigger reason",
             [({"reason": r}, v)
              for r, v in sorted(snapshot["flush_reasons"].items())]
             or [({}, 0)])
    w.scalar("dispatched_total", "counter",
             "Flushes dispatched to the device",
             snapshot["n_dispatched"])
    w.scalar("inflight_flushes", "gauge",
             "Flushes currently dispatched and not completed",
             snapshot["inflight_now"])
    w.scalar("inflight_flushes_max", "gauge",
             "High-watermark of concurrently in-flight flushes",
             snapshot["inflight_max"])
    w.scalar("overlapped_dispatches_total", "counter",
             "Dispatches that found the device already busy",
             snapshot["overlapped_dispatches"])
    w.scalar("device_idle_seconds_total", "counter",
             "Estimated seconds the device sat idle between flushes",
             snapshot["device_idle_s_est"])
    w.scalar("solve_seconds_total", "counter",
             "Cumulative dispatch-to-complete device service time",
             snapshot["solve_seconds"])
    w.scalar("assemble_seconds_total", "counter",
             "Cumulative host-side flush assembly time",
             snapshot["assemble_seconds"])
    w.scalar("throughput_lps", "gauge",
             "Solved LPs per second over the active traffic window",
             snapshot["throughput_lps"])
    w.family("latency_seconds", "summary",
             "End-to-end submit-to-result latency (reservoir-sampled)",
             [({"quantile": "0.5"}, snapshot["latency_p50_ms"] / 1e3),
              ({"quantile": "0.99"}, snapshot["latency_p99_ms"] / 1e3)])
    w.scalar("latency_seconds_count", "counter",
             "Latency samples offered to the reservoir",
             snapshot["latency_seen"])
    w.scalar("padding_waste_problems_ratio", "gauge",
             "Fraction of solved problem slots that were padding",
             snapshot["padding_waste_problems"])
    w.scalar("padding_waste_cells_ratio", "gauge",
             "Fraction of solved constraint cells that were padding",
             snapshot["padding_waste_cells"])
    w.family("errors_total", "counter",
             "Scheduler-side errors by kind",
             [({"kind": k}, v)
              for k, v in sorted(snapshot["errors"].items())]
             or [({}, 0)])
    cache = snapshot.get("cache")
    if cache is not None:
        w.scalar("executables_built", "gauge",
                 "Distinct compiled flush executables", cache["size"])
        w.scalar("executable_cache_hits_total", "counter",
                 "Executable cache hits", cache["hits"])
        w.scalar("executable_cache_misses_total", "counter",
                 "Executable cache misses", cache["misses"])

    # -- RPC plane --------------------------------------------------------
    if rpc is not None:
        w.family("rpc_requests_total", "counter",
                 "HTTP requests by endpoint and status code",
                 [({"endpoint": e, "code": str(c)}, v)
                  for (e, c), v in sorted(rpc["requests"].items())]
                 or [({}, 0)])
        w.family("rpc_shed_total", "counter",
                 "Requests shed before solving, by reason",
                 [({"reason": r}, v)
                  for r, v in sorted(rpc["shed"].items())]
                 or [({}, 0)])
        w.scalar("rpc_inprogress", "gauge",
                 "Solve requests currently being handled",
                 rpc["inprogress"])
        w.scalar("rpc_lps_accepted_total", "counter",
                 "LPs admitted past admission control",
                 rpc["lps_accepted"])
    if quotas is not None:
        w.family("rpc_quota_admitted_total", "counter",
                 "LPs admitted by the per-tenant token bucket",
                 [({"tenant": t}, q["admitted"])
                  for t, q in sorted(quotas.items())] or [({}, 0)])
        w.family("rpc_quota_rejected_total", "counter",
                 "LPs rejected by the per-tenant token bucket",
                 [({"tenant": t}, q["rejected"])
                  for t, q in sorted(quotas.items())] or [({}, 0)])
        w.family("rpc_quota_tokens", "gauge",
                 "Tokens currently available per tenant",
                 [({"tenant": t}, q["tokens"])
                  for t, q in sorted(quotas.items())] or [({}, 0)])
    return w.render()


def validate_exposition(text: str) -> None:
    """Cheap structural check of an exposition body (used by tests and
    the bench): every non-comment line is ``name{labels} value`` with a
    finite float value; raises ValueError otherwise."""
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        try:
            _, value = line.rsplit(" ", 1)
            v = float(value)
        except ValueError:
            raise ValueError(f"malformed sample line: {line!r}")
        if not math.isfinite(v):
            raise ValueError(f"non-finite sample value: {line!r}")
