"""SLO-driven batching: derive ``max_batch``/``max_wait_s`` per bucket
from *measured* flush latency instead of a guessed timer.

A request's worst-case latency through the scheduler decomposes as::

    wait-trigger timer  +  flush ahead of it  +  its own flush
    (max_wait_s)           (~est_flush_s)        (~est_flush_s)

The tuning table (:mod:`repro.tune`) already records the measured
µs/LP for every (backend, dtype, m-bucket, batch-bucket) shape class —
that is exactly an estimate of flush service time:
``est_flush_s(b) = us_per_lp * b_pad / n_devices``.  Given a stated p99
target, the controller solves the decomposition per m-bucket:

* cap ``max_batch`` so one flush's service time stays within
  ``service_fraction`` of the target (big-m buckets batch less);
* spend the *rest* of the budget on the wait trigger:
  ``max_wait_s = target - 2 * est_flush_s`` (clamped) — measured-slow
  buckets flush sooner, measured-fast buckets are allowed to
  accumulate bigger, more device-efficient batches.

Only ``source == "measured"`` table entries participate (the bundled
heuristic-seeded TPU placeholders carry sentinel timings that would
produce nonsense waits); buckets without a measured entry keep the
scheduler-wide defaults, so the controller degrades to exactly the
pre-SLO behaviour when no measurements exist.

:meth:`SLOController.install` wires the plans into the scheduler's
per-bucket limits hook (:meth:`BatchScheduler.set_bucket_policy`) and
tightens the scheduler-wide ``max_wait_s`` to the tightest planned
wait so the timer tick is fine-grained enough to honour it.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

from repro.serve_lp.buckets import bucket_batch

# Never plan a wait below this: a sub-millisecond timer burns a CPU on
# tick overhead for no batching benefit.
MIN_WAIT_S = 1e-3

# Never spend more than half the p99 target waiting: the other half
# must cover the two flush service times in the decomposition.
MAX_WAIT_FRACTION = 0.5


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """The derived batching limits for one m-bucket.

    ``allow_fuse`` is the fused-flush policy: whether this bucket may
    be folded into a cross-bucket fused flush unit.  Fusing solves the
    bucket's requests at a *larger* ``m_pad`` (the biggest member's),
    so the controller vetoes it when the measured timing at the next
    ladder rung would blow the flush-service budget."""

    bucket_m: int
    max_batch: int
    max_wait_s: float
    est_flush_s: Optional[float]   # None when no measured entry
    source: str                    # "measured" | "default"
    allow_fuse: bool = True


class SLOController:
    """Derives and installs per-bucket batching limits for a p99 target.

    ``table`` overrides the process-wide active tuning table (tests);
    ``device_kind`` overrides the measured-device key.  Plans are
    computed lazily per bucket and cached — the scheduler's submit path
    consults the installed policy on every request.
    """

    def __init__(self, target_p99_s: float, *,
                 service_fraction: float = 0.5,
                 min_batch: int = 8,
                 table=None,
                 device_kind: Optional[str] = None):
        if not target_p99_s > 0.0:
            raise ValueError(f"target_p99_s={target_p99_s} must be > 0")
        if not 0.0 < service_fraction < 1.0:
            raise ValueError(
                f"service_fraction={service_fraction} must be in (0, 1)")
        self.target_p99_s = float(target_p99_s)
        self.service_fraction = float(service_fraction)
        self.min_batch = int(min_batch)
        self._table = table
        self._device_kind = device_kind
        self._plans: Dict[int, BucketPlan] = {}
        self._lock = threading.Lock()
        self._scheduler = None

    # -- the planning model ----------------------------------------------

    def _active_table(self):
        if self._table is not None:
            return self._table
        try:
            from repro.tune.table import active_table
            return active_table()
        except Exception:   # tuning must never take serving down
            return None

    def _measured_us_per_lp(self, spec, bm: int,
                            batch: int) -> Optional[float]:
        """Measured µs/LP for this bucket's resolved backend, or None.
        Heuristic-seeded entries are ignored — the controller only
        trusts timings that were actually run."""
        table = self._active_table()
        if table is None:
            return None
        try:
            entry = table.lookup(backend=spec.backend, dtype=spec.dtype,
                                 m=bm, batch=batch,
                                 device_kind=self._device_kind)
        except Exception:
            return None
        if entry is None or entry.source != "measured":
            return None
        return float(entry.us_per_lp)

    def plan_for(self, scheduler, bm: int) -> BucketPlan:
        """The (cached) plan for one m-bucket of one scheduler."""
        with self._lock:
            hit = self._plans.get(bm)
            if hit is not None:
                return hit
        default = BucketPlan(
            bucket_m=bm, max_batch=scheduler.max_batch,
            max_wait_s=scheduler.max_wait_s, est_flush_s=None,
            source="default")
        try:
            plan = self._derive(scheduler, bm) or default
        except Exception as e:
            scheduler.metrics.record_error(
                "slo_plan",
                warn=f"serve_lp.rpc: SLO planning failed for "
                     f"bucket_m={bm} ({e!r}); using scheduler defaults")
            plan = default
        with self._lock:
            self._plans[bm] = plan
        return plan

    def _derive(self, scheduler, bm: int) -> Optional[BucketPlan]:
        spec = scheduler.spec.resolve_for_shape(bm, scheduler.max_batch)
        us = self._measured_us_per_lp(spec, bm, scheduler.max_batch)
        if us is None:
            return None
        n_dev = max(1, scheduler.n_devices)
        tile = spec.tile or 1
        unit = scheduler._unit_for_tile(tile)

        def est_flush_s(batch: int) -> float:
            # One flush solves b_pad (batch rounded up the padding
            # ladder) problems split across the devices the layout
            # actually uses — under mesh sharding an underfull flush
            # occupies fewer than n_dev devices, so its service time
            # does not shrink with devices it never touched.
            b_pad = bucket_batch(batch, unit)
            used = max(1, min(n_dev, -(-b_pad // tile)))
            return us * b_pad * 1e-6 / used

        target = self.target_p99_s
        max_batch = scheduler.max_batch
        while (max_batch > self.min_batch
               and est_flush_s(max_batch) > self.service_fraction * target):
            max_batch = max(self.min_batch, max_batch // 2)
        est = est_flush_s(max_batch)
        wait = target - 2.0 * est
        wait = min(max(wait, MIN_WAIT_S), MAX_WAIT_FRACTION * target)
        # Fused-flush policy: fusing solves this bucket at a larger
        # m_pad.  If the next ladder rung has a measured timing and a
        # same-size flush there would blow the service budget, keep the
        # bucket out of fused units; an unmeasured rung stays fusable
        # (the scheduler's fuse_max_m_ratio still bounds the blowup).
        allow_fuse = True
        spec2 = scheduler.spec.resolve_for_shape(2 * bm,
                                                 scheduler.max_batch)
        us2 = self._measured_us_per_lp(spec2, 2 * bm, scheduler.max_batch)
        if us2 is not None:
            tile2 = spec2.tile or 1
            b2 = bucket_batch(max_batch, scheduler._unit_for_tile(tile2))
            used2 = max(1, min(n_dev, -(-b2 // tile2)))
            est2 = us2 * b2 * 1e-6 / used2
            allow_fuse = est2 <= self.service_fraction * target
        return BucketPlan(bucket_m=bm, max_batch=max_batch,
                          max_wait_s=wait, est_flush_s=est,
                          source="measured", allow_fuse=allow_fuse)

    # -- wiring -----------------------------------------------------------

    def install(self, scheduler, *, m_max: int = 1024) -> None:
        """Point the scheduler's per-bucket limits hook at this
        controller and pre-plan the bucket ladder up to ``m_max`` so
        the scheduler-wide ``max_wait_s`` (the timer tick source) can
        be tightened to the tightest planned wait before the timer
        thread starts."""
        self._scheduler = scheduler
        # The same geometric ladder bucket_m() walks, from the
        # scheduler's own base (8 dense / LANE kernel).
        ladder, m = [], scheduler.bucket_base
        while m <= max(m_max, scheduler.bucket_base):
            ladder.append(m)
            m *= 2
        waits = [self.plan_for(scheduler, bm).max_wait_s
                 for bm in ladder]
        scheduler.max_wait_s = min(waits + [scheduler.max_wait_s])

        def policy(bm: int):
            plan = self.plan_for(scheduler, bm)
            return plan.max_batch, plan.max_wait_s, plan.allow_fuse

        scheduler.set_bucket_policy(policy)

    def plans(self) -> Dict[int, BucketPlan]:
        """Plans derived so far (for reporting/metrics)."""
        with self._lock:
            return dict(self._plans)
