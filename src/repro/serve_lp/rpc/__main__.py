"""CLI entry for the RPC server: ``python -m repro.serve_lp.rpc``.

The production launch path is ``scripts/serve_entrypoint.sh``, which
sets the measured-fast runtime environment (tcmalloc preload, XLA
flags, log levels) and then execs this module.
"""
from __future__ import annotations

import argparse
import asyncio
import os

from repro.obs import FlightRecorder, Tracer, setup_logging
from repro.obs.profiler import ProfileSession
from repro.serve_lp.rpc.admission import AdmissionPolicy
from repro.serve_lp.rpc.quota import QuotaManager
from repro.serve_lp.rpc.server import RpcServer, make_frontend
from repro.solver import SolverSpec


def _maybe_init_distributed() -> None:
    """Multi-host seam: when ``SERVE_COORDINATOR`` is set, join the
    multi-process JAX runtime before any device query.

    This is where multi-host serving plugs into the MeshLayout planner
    (``serve_lp.mesh_layout``): after ``jax.distributed.initialize``,
    ``jax.devices()`` spans every host and future layouts gain the
    reserved ``hosts`` mesh axis.  Single-host launches (no env) skip
    this entirely.  Companion envs: ``SERVE_NUM_PROCESSES`` and
    ``SERVE_PROCESS_ID``.
    """
    coordinator = os.environ.get("SERVE_COORDINATOR")
    if not coordinator:
        return
    import jax

    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=int(os.environ["SERVE_NUM_PROCESSES"]),
        process_id=int(os.environ["SERVE_PROCESS_ID"]))


def main(argv=None) -> None:
    _maybe_init_distributed()
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve_lp.rpc",
        description="HTTP front end for the batched 2-D LP solver")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--method", default="rgb",
                    choices=("rgb", "kernel", "naive", "pdhg"),
                    help="solver backend for every flush")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="scheduler-wide size trigger (the SLO "
                         "controller may cap it lower per bucket)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="scheduler-wide wait trigger")
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--no-pipeline", action="store_true")
    ap.add_argument("--target-p99-ms", type=float, default=None,
                    help="enable the SLO controller: derive per-bucket "
                         "max_batch/max_wait from measured flush "
                         "latency to hold this p99")
    ap.add_argument("--m-max", type=int, default=4096,
                    help="reject LPs with more constraints than this")
    ap.add_argument("--batch-max", type=int, default=1024,
                    help="reject requests with more LPs than this")
    ap.add_argument("--max-pending", type=int, default=4096,
                    help="shed (429) when this many LPs are queued and "
                         "the in-flight depth is at its bound")
    ap.add_argument("--max-queue-age-ms", type=float, default=500.0,
                    help="shed (429) when the oldest queued request "
                         "has waited this long")
    ap.add_argument("--quota-rate", type=float, default=10_000.0,
                    help="per-tenant sustained LPs/s")
    ap.add_argument("--quota-burst", type=float, default=2_000.0,
                    help="per-tenant instantaneous LP burst")
    ap.add_argument("--log-format", default="text",
                    choices=("text", "json"),
                    help="stdout log format; json emits one structured "
                         "object per line with trace_id/tenant from "
                         "the active request context")
    ap.add_argument("--trace", action="store_true",
                    help="enable end-to-end span tracing (repro.obs); "
                         "spans are pullable at GET /debug/trace")
    ap.add_argument("--trace-capacity", type=int, default=16384,
                    help="span ring-buffer capacity (with --trace)")
    ap.add_argument("--flight-spool", default=None, metavar="DIR",
                    help="enable the flight recorder: dump ring + "
                         "scheduler state to DIR on errors / SLO "
                         "violations, browsable at GET /debug/flight")
    ap.add_argument("--flight-p99-ms", type=float, default=None,
                    help="also snapshot when request p99 exceeds this "
                         "(needs --flight-spool)")
    ap.add_argument("--jax-profile-dir", default=None, metavar="DIR",
                    help="run a jax.profiler session into DIR for the "
                         "server's lifetime and annotate each device "
                         "launch with its flush label")
    args = ap.parse_args(argv)

    setup_logging(fmt=args.log_format)

    tracer = None
    if args.trace or args.jax_profile_dir:
        tracer = Tracer(enabled=True, capacity=args.trace_capacity,
                        annotate_device=bool(args.jax_profile_dir))
    recorder = None
    if args.flight_spool:
        recorder = FlightRecorder(
            args.flight_spool, tracer=tracer,
            p99_threshold_s=(args.flight_p99_ms / 1e3
                             if args.flight_p99_ms is not None
                             else None))

    frontend = make_frontend(
        SolverSpec(backend=args.method),
        max_batch=args.max_batch,
        max_wait_s=args.max_wait_ms / 1e3,
        max_inflight=args.max_inflight,
        pipeline=not args.no_pipeline,
        policy=AdmissionPolicy(
            m_max=args.m_max, batch_max=args.batch_max,
            max_pending=args.max_pending,
            max_queue_age_s=args.max_queue_age_ms / 1e3),
        quotas=QuotaManager(rate=args.quota_rate,
                            burst=args.quota_burst),
        target_p99_s=(args.target_p99_ms / 1e3
                      if args.target_p99_ms is not None else None),
        tracer=tracer,
        recorder=recorder,
    )

    profile = (ProfileSession(args.jax_profile_dir)
               if args.jax_profile_dir else None)
    if profile is not None:
        profile.start()

    async def _serve():
        server = RpcServer(frontend, args.host, args.port)
        await server.start()
        slo = ("off" if frontend.slo is None
               else f"p99<={args.target_p99_ms:.0f}ms")
        obs = "trace" if tracer is not None else "no-trace"
        if recorder is not None:
            obs += f"+flight:{args.flight_spool}"
        print(f"[serve_lp.rpc] listening on http://{args.host}:"
              f"{server.port}  backend={args.method} "
              f"devices={frontend.scheduler.n_devices} slo={slo} "
              f"obs={obs}",
              flush=True)
        try:
            await server.serve_forever()
        finally:
            await server.aclose()

    try:
        asyncio.run(_serve())
    except KeyboardInterrupt:
        pass
    finally:
        if profile is not None:
            profile.stop()


if __name__ == "__main__":
    main()
