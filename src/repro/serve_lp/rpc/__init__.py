"""Network front end for the LP serving layer.

Turns open-loop client traffic into well-formed, admission-controlled
scheduler flushes::

    HTTP/1.1 (asyncio, no framework)         server.RpcServer
        -> routing + solve pipeline          server.LPFrontend
            -> validation / deadline / 4xx   admission
            -> per-tenant token buckets      quota
            -> load shedding (429)           admission.check_backpressure
            -> SLO-derived batch limits      slo.SLOController
        -> BatchScheduler submit/futures     repro.serve_lp.scheduler
    GET /metrics                             prometheus (text exposition)

Quickstart (production path is ``scripts/serve_entrypoint.sh``)::

    python -m repro.serve_lp.rpc --port 8080 --target-p99-ms 50
    curl -s localhost:8080/v1/solve -XPOST -H 'X-Tenant: me' \\
        -d '{"A": [[1,0],[0,1],[-1,-1]], "b": [1,1,-0.5], "c": [1,1]}'
"""
from repro.serve_lp.rpc.admission import (AdmissionPolicy, RpcError,
                                          check_backpressure,
                                          deadline_budget_s,
                                          parse_solve_payload)
from repro.serve_lp.rpc.prometheus import (render_metrics,
                                           validate_exposition)
from repro.serve_lp.rpc.quota import (DEFAULT_TENANT, QuotaManager,
                                      TokenBucket)
from repro.serve_lp.rpc.server import (LPFrontend, Request, Response,
                                       RpcCounters, RpcServer,
                                       make_frontend, run_in_thread)
from repro.serve_lp.rpc.slo import BucketPlan, SLOController

__all__ = [
    "AdmissionPolicy", "BucketPlan", "DEFAULT_TENANT", "LPFrontend",
    "QuotaManager", "Request", "Response", "RpcCounters", "RpcError",
    "RpcServer", "SLOController", "TokenBucket", "check_backpressure",
    "deadline_budget_s", "make_frontend", "parse_solve_payload",
    "render_metrics", "run_in_thread", "validate_exposition",
]
