"""The asyncio HTTP front end over :class:`BatchScheduler`.

Two layers, deliberately separable:

* :class:`LPFrontend` — the request handler.  ``await
  frontend.handle(Request)`` runs the whole admission pipeline
  (validation -> deadline -> backpressure -> quota -> submit -> await
  futures) and returns a :class:`Response`.  It never touches a
  socket, so tests drive it directly with synthetic requests;
* :class:`RpcServer` — a minimal HTTP/1.1 server (stdlib ``asyncio``
  streams, keep-alive, Content-Length framing; no framework
  dependency) that parses bytes into :class:`Request` and writes
  :class:`Response` back.

Why asyncio and not a thread pool: micro-batching *needs* many
requests concurrently in flight — a thread-per-request front end at
batch-128 concurrency costs 128 stacks and a scheduler fight, while
one event loop holds thousands of pending solves as cheap coroutines
awaiting their scheduler futures.  The two blocking edges are kept off
the loop: ``submit`` (which can run an inline size-triggered flush and
block on the ``max_inflight`` backpressure condition variable) runs in
the default executor, and result waiting awaits the wrapped
``concurrent.futures.Future`` with the request's deadline budget as
timeout — on expiry the futures are cancelled, and the scheduler drops
cancelled work at flush time instead of solving it.

Endpoints::

    POST /v1/solve   single {"A","b","c"} or batch {"problems":[...]}
                     headers: X-Tenant (quota key),
                              X-Deadline-Ms (latency budget)
    GET  /metrics    Prometheus text exposition
    GET  /healthz    process liveness (always 200 while serving)
    GET  /readyz     scheduler accepting work (503 once closed)
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve_lp.rpc.admission import (TENANT_HEADER, AdmissionPolicy,
                                          RpcError, check_backpressure,
                                          deadline_budget_s,
                                          parse_solve_payload)
from repro.serve_lp.rpc.prometheus import CONTENT_TYPE, render_metrics
from repro.serve_lp.rpc.quota import DEFAULT_TENANT, QuotaManager
from repro.serve_lp.rpc.slo import SLOController

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            422: "Unprocessable Entity", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}

# A header/request-line longer than this is hostile, not a client.
_MAX_HEADER_LINE = 16 << 10
_MAX_HEADERS = 64


@dataclasses.dataclass
class Request:
    """One parsed HTTP request (header keys lower-cased)."""

    method: str
    path: str
    headers: Dict[str, str]
    body: bytes = b""


@dataclasses.dataclass
class Response:
    """One HTTP response; ``json_response``/``text_response`` build it."""

    status: int
    body: bytes
    content_type: str = "application/json"
    headers: Dict[str, str] = dataclasses.field(default_factory=dict)

    def encode(self, *, close: bool = False) -> bytes:
        reason = _REASONS.get(self.status, "Unknown")
        head = [f"HTTP/1.1 {self.status} {reason}",
                f"Content-Type: {self.content_type}",
                f"Content-Length: {len(self.body)}"]
        head += [f"{k}: {v}" for k, v in self.headers.items()]
        if close:
            head.append("Connection: close")
        return ("\r\n".join(head) + "\r\n\r\n").encode("ascii") + self.body


def json_response(status: int, obj: Any,
                  headers: Optional[Dict[str, str]] = None) -> Response:
    return Response(status, json.dumps(obj).encode("utf-8"),
                    headers=dict(headers or {}))


def text_response(status: int, text: str) -> Response:
    return Response(status, text.encode("utf-8"),
                    content_type="text/plain; charset=utf-8")


def error_response(err: RpcError) -> Response:
    headers = {}
    body: Dict[str, Any] = {"error": {
        "code": err.code, "message": err.message, "status": err.status}}
    if err.retry_after_s is not None and math.isfinite(err.retry_after_s):
        # Retry-After is integer seconds on the wire; the body carries
        # the precise hint for clients that can back off sub-second.
        headers["Retry-After"] = str(max(1, math.ceil(err.retry_after_s)))
        body["error"]["retry_after_ms"] = round(err.retry_after_s * 1e3, 3)
    return json_response(err.status, body, headers)


class RpcCounters:
    """Thread-safe RPC-plane counters exported at /metrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self.requests: Dict[Tuple[str, int], int] = {}
        self.shed: Dict[str, int] = {}
        self.inprogress = 0
        self.lps_accepted = 0

    def record_request(self, endpoint: str, status: int) -> None:
        with self._lock:
            key = (endpoint, int(status))
            self.requests[key] = self.requests.get(key, 0) + 1

    def record_shed(self, reason: str) -> None:
        with self._lock:
            self.shed[reason] = self.shed.get(reason, 0) + 1

    def record_accepted(self, n_lps: int) -> None:
        with self._lock:
            self.lps_accepted += int(n_lps)

    def enter(self) -> None:
        with self._lock:
            self.inprogress += 1

    def exit(self) -> None:
        with self._lock:
            self.inprogress -= 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"requests": dict(self.requests),
                    "shed": dict(self.shed),
                    "inprogress": self.inprogress,
                    "lps_accepted": self.lps_accepted}


class LPFrontend:
    """The socket-free request handler: admission control + scheduler.

    Owns the admission policy, per-tenant quotas, the optional SLO
    controller, and the RPC counters.  :meth:`start` installs the SLO
    plans and starts the scheduler's wait-trigger timer; :meth:`close`
    shuts the scheduler down (readyz goes 503, healthz stays 200 so
    orchestrators can tell "draining" from "dead").
    """

    def __init__(self, scheduler, *,
                 policy: Optional[AdmissionPolicy] = None,
                 quotas: Optional[QuotaManager] = None,
                 slo: Optional[SLOController] = None):
        self.scheduler = scheduler
        self.policy = policy if policy is not None else AdmissionPolicy()
        self.quotas = quotas if quotas is not None else QuotaManager()
        self.slo = slo
        self.counters = RpcCounters()
        self._dtype = np.dtype(scheduler.spec.dtype)
        self._started = False

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "LPFrontend":
        if not self._started:
            if self.slo is not None:
                self.slo.install(self.scheduler,
                                 m_max=self.policy.m_max)
            self.scheduler.start()
            self._started = True
        return self

    def close(self) -> None:
        if self._started:
            self._started = False
            self.scheduler.close()

    @property
    def ready(self) -> bool:
        return self._started and not self.scheduler.closed

    # -- routing ----------------------------------------------------------

    async def handle(self, req: Request) -> Response:
        """Route one request; always returns a Response (typed errors
        included) and records it in the RPC counters."""
        endpoint, resp = await self._route(req)
        self.counters.record_request(endpoint, resp.status)
        return resp

    async def _route(self, req: Request) -> Tuple[str, Response]:
        if req.path == "/v1/solve":
            if req.method != "POST":
                return "solve", error_response(RpcError(
                    405, "method_not_allowed", "use POST /v1/solve"))
            return "solve", await self._solve(req)
        if req.path == "/metrics":
            return "metrics", self._metrics()
        if req.path == "/healthz":
            return "healthz", text_response(200, "ok\n")
        if req.path == "/readyz":
            if self.ready:
                return "readyz", text_response(200, "ready\n")
            return "readyz", text_response(503, "not ready\n")
        return "other", error_response(RpcError(
            404, "not_found", f"no route for {req.method} {req.path}"))

    # -- the solve pipeline ----------------------------------------------

    async def _solve(self, req: Request) -> Response:
        t0 = time.perf_counter()
        self.counters.enter()
        try:
            return await self._admit_and_solve(req, t0)
        except RpcError as e:
            if e.status in (429, 504):
                self.counters.record_shed(e.code)
            return error_response(e)
        except Exception as e:   # never leak internals to the wire
            self.scheduler.metrics.record_error(
                "rpc_internal",
                warn=f"serve_lp.rpc: internal error handling a "
                     f"request ({e!r})")
            return error_response(RpcError(
                500, "internal", "internal server error"))
        finally:
            self.counters.exit()

    async def _admit_and_solve(self, req: Request,
                               t0: float) -> Response:
        policy = self.policy
        # 1. validation — typed 4xx before any scheduler state moves.
        problems, is_batch = parse_solve_payload(
            req.body, self._dtype, policy)
        payload_deadline = None
        if b"deadline_ms" in req.body:
            try:   # only re-parse when the field can exist
                payload_deadline = json.loads(req.body).get("deadline_ms")
            except ValueError:
                payload_deadline = None
        # 2. deadline — an already-expired budget is rejected, not solved.
        budget = deadline_budget_s(req.headers, payload_deadline, policy)
        # 3. backpressure — shed instead of queueing unboundedly.
        # Before quota: a request the server is about to 429/503
        # anyway must not also cost the tenant tokens.
        check_backpressure(self.scheduler, policy)
        if not self.ready:
            raise RpcError(503, "not_ready",
                           "scheduler is not accepting work")
        # 4. quota — per-tenant token bucket, priced Retry-After.
        tenant = req.headers.get(TENANT_HEADER, DEFAULT_TENANT)
        retry = self.quotas.admit(tenant, cost=float(len(problems)))
        if retry == math.inf:
            raise RpcError(
                413, "batch_exceeds_burst",
                f"{len(problems)} LPs exceeds tenant {tenant!r}'s "
                "burst allowance; split the batch")
        if retry > 0.0:
            raise RpcError(
                429, "quota_exhausted",
                f"tenant {tenant!r} is over its rate quota",
                retry_after_s=retry)
        # 5. submit — in the executor: an inline size-triggered flush
        # can block on the max_inflight condition variable, and that
        # must never stall the event loop.
        loop = asyncio.get_running_loop()
        sched = self.scheduler

        def _submit_all():
            return [sched.submit(A, b, c) for A, b, c in problems]

        try:
            futures = await loop.run_in_executor(None, _submit_all)
        except RuntimeError as e:     # closed under our feet
            raise RpcError(503, "not_ready", str(e))
        self.counters.record_accepted(len(problems))
        # 6. await results within the remaining budget; on expiry the
        # futures are cancelled so still-queued work is dropped at
        # flush time instead of solved.
        timeout = None
        if budget is not None:
            timeout = budget - (time.perf_counter() - t0)
            if timeout <= 0.0:
                for f in futures:
                    f.cancel()
                raise RpcError(504, "deadline_exceeded",
                               "deadline expired before dispatch")
        gathered = asyncio.gather(
            *[asyncio.wrap_future(f) for f in futures])
        try:
            results = await asyncio.wait_for(gathered, timeout=timeout)
        except asyncio.TimeoutError:
            for f in futures:
                f.cancel()
            raise RpcError(
                504, "deadline_exceeded",
                f"deadline of {budget * 1e3:.0f}ms expired while "
                "solving")
        except asyncio.CancelledError:
            for f in futures:
                f.cancel()
            raise
        except Exception as e:
            self.scheduler.metrics.record_error(
                "rpc_solve", warn=f"serve_lp.rpc: solve failed ({e!r})")
            raise RpcError(500, "solve_failed",
                           "solve failed; details in server logs and "
                           "the repro_serve_errors_total counter")
        body = [{
            "x": [float(r.x[0]), float(r.x[1])],
            "feasible": bool(r.feasible),
            "objective": float(r.objective),
            "m": int(r.m),
            "bucket_m": int(r.bucket_m),
            "batch_size": int(r.batch_size),
            "latency_ms": round(r.latency_s * 1e3, 3),
        } for r in results]
        if is_batch:
            return json_response(200, {"results": body, "n": len(body)})
        return json_response(200, {"result": body[0]})

    # -- observability ----------------------------------------------------

    def _metrics(self) -> Response:
        snap = self.scheduler.metrics.snapshot(
            self.scheduler.cache.stats())
        text = render_metrics(
            snap, rpc=self.counters.snapshot(),
            quotas=self.quotas.snapshot(),
            slo=self.slo.plans() if self.slo is not None else None)
        return Response(200, text.encode("utf-8"),
                        content_type=CONTENT_TYPE)


# -- the HTTP/1.1 byte layer ----------------------------------------------

async def _read_request(reader: asyncio.StreamReader,
                        body_max: int) -> Optional[Request]:
    """Parse one request off a keep-alive connection; None on clean
    EOF; raises RpcError(400/413) on malformed/oversized input."""
    try:
        line = await reader.readline()
    except ConnectionError:
        return None
    except (ValueError, asyncio.LimitOverrunError):
        # StreamReader.readline reports a line longer than the stream
        # limit as ValueError — answer 400, don't drop the connection
        # with an unhandled task exception.
        raise RpcError(400, "bad_request", "request line too long")
    if not line:
        return None
    if len(line) > _MAX_HEADER_LINE:
        raise RpcError(400, "bad_request", "request line too long")
    try:
        method, path, version = line.decode("ascii").split()
    except ValueError:
        raise RpcError(400, "bad_request",
                       f"malformed request line {line!r}")
    if not version.startswith("HTTP/1."):
        raise RpcError(400, "bad_request",
                       f"unsupported protocol {version!r}")
    headers: Dict[str, str] = {}
    for _ in range(_MAX_HEADERS):
        try:
            line = await reader.readline()
        except (ValueError, asyncio.LimitOverrunError):
            raise RpcError(400, "bad_request", "header line too long")
        if line in (b"\r\n", b"\n", b""):
            break
        if len(line) > _MAX_HEADER_LINE:
            raise RpcError(400, "bad_request", "header line too long")
        name, _, value = line.decode("latin-1").partition(":")
        headers[name.strip().lower()] = value.strip()
    else:
        raise RpcError(400, "bad_request", "too many headers")
    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError:
            raise RpcError(400, "bad_request", "bad Content-Length")
        if n < 0:
            raise RpcError(400, "bad_request", "bad Content-Length")
        if n > body_max:
            raise RpcError(413, "body_too_large",
                           f"request body {n}B exceeds {body_max}B")
        body = await reader.readexactly(n)
    elif headers.get("transfer-encoding"):
        raise RpcError(400, "bad_request",
                       "chunked bodies are not supported; send "
                       "Content-Length")
    return Request(method=method.upper(), path=path.split("?", 1)[0],
                   headers=headers, body=body)


class RpcServer:
    """asyncio TCP server wrapping an :class:`LPFrontend`.

    ``await start()`` binds (``port=0`` picks a free port, re-read from
    ``self.port``) and starts the frontend; ``await aclose()`` stops
    accepting, then closes the frontend (final flush + drain).
    """

    def __init__(self, frontend: LPFrontend, host: str = "127.0.0.1",
                 port: int = 0):
        self.frontend = frontend
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "RpcServer":
        self.frontend.start()
        self._server = await asyncio.start_server(
            self._on_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        # Scheduler close blocks on drain — keep it off the loop.
        await asyncio.get_running_loop().run_in_executor(
            None, self.frontend.close)

    async def _on_connection(self, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter) -> None:
        body_max = self.frontend.policy.body_max_bytes
        try:
            while True:
                try:
                    req = await _read_request(reader, body_max)
                except RpcError as e:
                    writer.write(error_response(e).encode(close=True))
                    await writer.drain()
                    break
                except (asyncio.IncompleteReadError, ConnectionError):
                    break
                if req is None:
                    break
                resp = await self.frontend.handle(req)
                close = (req.headers.get("connection", "").lower()
                         == "close")
                writer.write(resp.encode(close=close))
                await writer.drain()
                if close:
                    break
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass


def run_in_thread(frontend: LPFrontend, host: str = "127.0.0.1",
                  port: int = 0) -> Tuple[int, Callable[[], None]]:
    """Run an :class:`RpcServer` on a daemon thread with its own event
    loop; returns ``(bound_port, stop)``.  The bench and the
    real-socket tests use this — production runs ``python -m
    repro.serve_lp.rpc`` (see ``__main__``)."""
    started = threading.Event()
    state: Dict[str, Any] = {}

    async def _main():
        server = RpcServer(frontend, host, port)
        await server.start()
        state["port"] = server.port
        state["loop"] = asyncio.get_running_loop()
        state["stop"] = asyncio.Event()
        started.set()
        try:
            await state["stop"].wait()
        finally:
            await server.aclose()

    def _run():
        try:
            asyncio.run(_main())
        except Exception as e:   # surface bind errors to the waiter
            state["error"] = e
            started.set()

    thread = threading.Thread(target=_run, name="serve-lp-rpc",
                              daemon=True)
    thread.start()
    if not started.wait(timeout=30.0):
        raise RuntimeError("RPC server failed to start within 30s")
    if "error" in state:
        raise state["error"]

    def stop() -> None:
        state["loop"].call_soon_threadsafe(state["stop"].set)
        thread.join(timeout=60.0)

    return state["port"], stop


# -- one-call construction -------------------------------------------------

def make_frontend(spec=None, *,
                  max_batch: int = 256,
                  max_wait_s: float = 0.005,
                  max_inflight: int = 2,
                  pipeline: bool = True,
                  policy: Optional[AdmissionPolicy] = None,
                  quotas: Optional[QuotaManager] = None,
                  target_p99_s: Optional[float] = None,
                  metrics=None) -> LPFrontend:
    """Build scheduler + admission + quota + SLO in one call — the
    shared construction path of ``__main__``, the bench's ``--rpc``
    mode, and tests."""
    from repro.serve_lp.scheduler import BatchScheduler
    scheduler = BatchScheduler(
        spec, max_batch=max_batch, max_wait_s=max_wait_s,
        max_inflight=max_inflight, pipeline=pipeline, metrics=metrics)
    slo = (SLOController(target_p99_s)
           if target_p99_s is not None else None)
    return LPFrontend(scheduler, policy=policy, quotas=quotas, slo=slo)
