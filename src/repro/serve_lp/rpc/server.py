"""The asyncio HTTP front end over :class:`BatchScheduler`.

Two layers, deliberately separable:

* :class:`LPFrontend` — the request handler.  ``await
  frontend.handle(Request)`` runs the whole admission pipeline
  (validation -> deadline -> backpressure -> quota -> submit -> await
  futures) and returns a :class:`Response`.  It never touches a
  socket, so tests drive it directly with synthetic requests;
* :class:`RpcServer` — a minimal HTTP/1.1 server (stdlib ``asyncio``
  streams, keep-alive, Content-Length framing; no framework
  dependency) that parses bytes into :class:`Request` and writes
  :class:`Response` back.

Why asyncio and not a thread pool: micro-batching *needs* many
requests concurrently in flight — a thread-per-request front end at
batch-128 concurrency costs 128 stacks and a scheduler fight, while
one event loop holds thousands of pending solves as cheap coroutines
awaiting their scheduler futures.  The two blocking edges are kept off
the loop: ``submit`` (which can run an inline size-triggered flush and
block on the ``max_inflight`` backpressure condition variable) runs in
the default executor, and result waiting awaits the wrapped
``concurrent.futures.Future`` with the request's deadline budget as
timeout — on expiry the futures are cancelled, and the scheduler drops
cancelled work at flush time instead of solving it.

Endpoints::

    POST /v1/solve   single {"A","b","c"} or batch {"problems":[...]}
                     headers: X-Tenant (quota key),
                              X-Deadline-Ms (latency budget),
                              X-Trace-Id (trace context, echoed back)
    GET  /metrics    Prometheus text exposition (histograms + exemplars)
    GET  /healthz    process liveness (always 200 while serving)
    GET  /readyz     scheduler accepting work (503 once closed)
    GET  /debug/trace[?trace_id=][&format=spans]
                     Chrome trace_event JSON of the span ring (load it
                     in Perfetto), optionally filtered to one trace
    GET  /debug/flight[?name=]
                     flight-recorder spool index / one snapshot body

Tracing: a ``POST /v1/solve`` whose scheduler has an enabled tracer
gets an ``rpc.handle`` span (accepting the caller's ``X-Trace-Id``
context or minting a root one) and an ``admit`` child covering the
admission pipeline; the scheduler then parents each per-LP ``request``
span under the handle span.  The trace id is echoed on every solve
response so clients can pull ``/debug/trace?trace_id=`` afterwards.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
import threading
import time
import urllib.parse
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.obs.export import to_chrome_trace
from repro.obs.trace import (TRACE_HEADER, new_trace_context,
                             parse_trace_header, spans_for_trace,
                             use_context)
from repro.serve_lp.rpc.admission import (TENANT_HEADER, AdmissionPolicy,
                                          RpcError, check_backpressure,
                                          deadline_budget_s,
                                          parse_solve_payload)
from repro.serve_lp.rpc.prometheus import CONTENT_TYPE, render_metrics
from repro.serve_lp.rpc.quota import DEFAULT_TENANT, QuotaManager
from repro.serve_lp.rpc.slo import SLOController

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            422: "Unprocessable Entity", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}

# A header/request-line longer than this is hostile, not a client.
_MAX_HEADER_LINE = 16 << 10
_MAX_HEADERS = 64

# Lower-cased wire header for trace contexts (headers dict keys are
# lower-cased by the parser).
_TRACE_HDR = TRACE_HEADER.lower()


@dataclasses.dataclass
class Request:
    """One parsed HTTP request (header keys lower-cased; ``query``
    holds the decoded query-string parameters, last value wins)."""

    method: str
    path: str
    headers: Dict[str, str]
    body: bytes = b""
    query: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Response:
    """One HTTP response; ``json_response``/``text_response`` build it."""

    status: int
    body: bytes
    content_type: str = "application/json"
    headers: Dict[str, str] = dataclasses.field(default_factory=dict)

    def encode(self, *, close: bool = False) -> bytes:
        reason = _REASONS.get(self.status, "Unknown")
        head = [f"HTTP/1.1 {self.status} {reason}",
                f"Content-Type: {self.content_type}",
                f"Content-Length: {len(self.body)}"]
        head += [f"{k}: {v}" for k, v in self.headers.items()]
        if close:
            head.append("Connection: close")
        return ("\r\n".join(head) + "\r\n\r\n").encode("ascii") + self.body


def json_response(status: int, obj: Any,
                  headers: Optional[Dict[str, str]] = None) -> Response:
    return Response(status, json.dumps(obj).encode("utf-8"),
                    headers=dict(headers or {}))


def text_response(status: int, text: str) -> Response:
    return Response(status, text.encode("utf-8"),
                    content_type="text/plain; charset=utf-8")


def error_response(err: RpcError) -> Response:
    headers = {}
    body: Dict[str, Any] = {"error": {
        "code": err.code, "message": err.message, "status": err.status}}
    if err.retry_after_s is not None and math.isfinite(err.retry_after_s):
        # Retry-After is integer seconds on the wire; the body carries
        # the precise hint for clients that can back off sub-second.
        headers["Retry-After"] = str(max(1, math.ceil(err.retry_after_s)))
        body["error"]["retry_after_ms"] = round(err.retry_after_s * 1e3, 3)
    return json_response(err.status, body, headers)


class RpcCounters:
    """Thread-safe RPC-plane counters exported at /metrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self.requests: Dict[Tuple[str, int], int] = {}
        self.shed: Dict[str, int] = {}
        self.inprogress = 0
        self.lps_accepted = 0

    def record_request(self, endpoint: str, status: int) -> None:
        with self._lock:
            key = (endpoint, int(status))
            self.requests[key] = self.requests.get(key, 0) + 1

    def record_shed(self, reason: str) -> None:
        with self._lock:
            self.shed[reason] = self.shed.get(reason, 0) + 1

    def record_accepted(self, n_lps: int) -> None:
        with self._lock:
            self.lps_accepted += int(n_lps)

    def enter(self) -> None:
        with self._lock:
            self.inprogress += 1

    def exit(self) -> None:
        with self._lock:
            self.inprogress -= 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"requests": dict(self.requests),
                    "shed": dict(self.shed),
                    "inprogress": self.inprogress,
                    "lps_accepted": self.lps_accepted}


class LPFrontend:
    """The socket-free request handler: admission control + scheduler.

    Owns the admission policy, per-tenant quotas, the optional SLO
    controller, and the RPC counters.  :meth:`start` installs the SLO
    plans and starts the scheduler's wait-trigger timer; :meth:`close`
    shuts the scheduler down (readyz goes 503, healthz stays 200 so
    orchestrators can tell "draining" from "dead").
    """

    def __init__(self, scheduler, *,
                 policy: Optional[AdmissionPolicy] = None,
                 quotas: Optional[QuotaManager] = None,
                 slo: Optional[SLOController] = None):
        self.scheduler = scheduler
        self.policy = policy if policy is not None else AdmissionPolicy()
        self.quotas = quotas if quotas is not None else QuotaManager()
        self.slo = slo
        self.counters = RpcCounters()
        self._dtype = np.dtype(scheduler.spec.dtype)
        self._started = False
        # Observability plumbing rides on whatever the scheduler was
        # built with — the RPC layer never owns a tracer of its own.
        self._tracer = getattr(scheduler, "tracer", None)
        self._recorder = getattr(scheduler, "recorder", None)

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "LPFrontend":
        if not self._started:
            if self.slo is not None:
                self.slo.install(self.scheduler,
                                 m_max=self.policy.m_max)
            self.scheduler.start()
            self._started = True
        return self

    def close(self) -> None:
        if self._started:
            self._started = False
            self.scheduler.close()

    @property
    def ready(self) -> bool:
        return self._started and not self.scheduler.closed

    # -- routing ----------------------------------------------------------

    async def handle(self, req: Request) -> Response:
        """Route one request; always returns a Response (typed errors
        included) and records it in the RPC counters."""
        endpoint, resp = await self._route(req)
        self.counters.record_request(endpoint, resp.status)
        return resp

    async def _route(self, req: Request) -> Tuple[str, Response]:
        if req.path == "/v1/solve":
            if req.method != "POST":
                return "solve", error_response(RpcError(
                    405, "method_not_allowed", "use POST /v1/solve"))
            return "solve", await self._solve(req)
        if req.path == "/metrics":
            return "metrics", self._metrics()
        if req.path == "/healthz":
            return "healthz", text_response(200, "ok\n")
        if req.path == "/readyz":
            if self.ready:
                return "readyz", text_response(200, "ready\n")
            return "readyz", text_response(503, "not ready\n")
        if req.path == "/debug/trace":
            return "debug_trace", self._debug_trace(req)
        if req.path == "/debug/flight":
            return "debug_flight", self._debug_flight(req)
        return "other", error_response(RpcError(
            404, "not_found", f"no route for {req.method} {req.path}"))

    # -- the solve pipeline ----------------------------------------------

    async def _solve(self, req: Request) -> Response:
        t0 = time.perf_counter()
        tracer = self._tracer
        ctx = hspan = None
        tenant = req.headers.get(TENANT_HEADER, DEFAULT_TENANT)
        if tracer is not None and tracer.enabled:
            # Accept the caller's context (malformed values fall back
            # to a fresh root — tracing never rejects a request).
            ctx = (parse_trace_header(req.headers.get(_TRACE_HDR))
                   or new_trace_context())
            hspan = tracer.start_span(
                "rpc.handle", ctx.trace_id, parent_id=ctx.span_id,
                t_start=t0, endpoint="solve", tenant=tenant)
        self.counters.enter()
        status: int = 500
        code: Optional[str] = None
        try:
            with use_context(
                    trace_id=(ctx.trace_id if ctx is not None else None),
                    span_id=(hspan.span_id if hspan is not None
                             else None),
                    tenant=tenant):
                resp = await self._admit_and_solve(req, t0, ctx, hspan)
            status = resp.status
        except RpcError as e:
            if e.status in (429, 504):
                self.counters.record_shed(e.code)
            if e.status == 504 and self._recorder is not None:
                # An SLO violation (missed deadline) is a flight-
                # recorder trigger: capture the queue/flush state that
                # made the budget impossible.
                self._recorder.trigger(f"slo:{e.code}")
            status, code = e.status, e.code
            resp = error_response(e)
        except Exception as e:   # never leak internals to the wire
            self.scheduler.metrics.record_error(
                "rpc_internal",
                warn=f"serve_lp.rpc: internal error handling a "
                     f"request ({e!r})")
            status, code = 500, "internal"
            resp = error_response(RpcError(
                500, "internal", "internal server error"))
        finally:
            self.counters.exit()
        if tracer is not None:
            if code is not None:
                tracer.end(hspan, status=status, code=code)
            else:
                tracer.end(hspan, status=status)
        if ctx is not None:
            # Echo the trace id so the client can pull
            # /debug/trace?trace_id= for this exact request.
            resp.headers.setdefault(TRACE_HEADER, ctx.trace_id)
        return resp

    async def _admit_and_solve(
            self, req: Request, t0: float,
            ctx=None, hspan=None) -> Response:
        policy = self.policy
        tracer = self._tracer
        aspan = None
        if ctx is not None:
            aspan = tracer.start_span(
                "admit", ctx.trace_id,
                parent_id=(hspan.span_id if hspan is not None
                           else ctx.span_id),
                t_start=t0)
        try:
            # 1. validation — typed 4xx before any scheduler state
            # moves.
            problems, is_batch = parse_solve_payload(
                req.body, self._dtype, policy)
            payload_deadline = None
            if b"deadline_ms" in req.body:
                try:   # only re-parse when the field can exist
                    payload_deadline = json.loads(
                        req.body).get("deadline_ms")
                except ValueError:
                    payload_deadline = None
            # 2. deadline — an already-expired budget is rejected, not
            # solved.
            budget = deadline_budget_s(
                req.headers, payload_deadline, policy)
            # 3. backpressure — shed instead of queueing unboundedly.
            # Before quota: a request the server is about to 429/503
            # anyway must not also cost the tenant tokens.
            check_backpressure(self.scheduler, policy)
            if not self.ready:
                raise RpcError(503, "not_ready",
                               "scheduler is not accepting work")
            # 4. quota — per-tenant token bucket, priced Retry-After.
            tenant = req.headers.get(TENANT_HEADER, DEFAULT_TENANT)
            retry = self.quotas.admit(tenant, cost=float(len(problems)))
            if retry == math.inf:
                raise RpcError(
                    413, "batch_exceeds_burst",
                    f"{len(problems)} LPs exceeds tenant {tenant!r}'s "
                    "burst allowance; split the batch")
            if retry > 0.0:
                raise RpcError(
                    429, "quota_exhausted",
                    f"tenant {tenant!r} is over its rate quota",
                    retry_after_s=retry)
        except RpcError as e:
            if tracer is not None:
                tracer.end(aspan, rejected=e.code)
            raise
        if tracer is not None:
            tracer.end(aspan, n_lps=len(problems))
        # 5. submit — in the executor: an inline size-triggered flush
        # can block on the max_inflight condition variable, and that
        # must never stall the event loop.
        loop = asyncio.get_running_loop()
        sched = self.scheduler
        # Per-LP request spans parent under the rpc.handle span.
        sub_ctx = (ctx.child_of(hspan.span_id)
                   if ctx is not None and hspan is not None else ctx)

        def _submit_all():
            return [sched.submit(A, b, c, trace=sub_ctx)
                    for A, b, c in problems]

        try:
            futures = await loop.run_in_executor(None, _submit_all)
        except RuntimeError as e:     # closed under our feet
            raise RpcError(503, "not_ready", str(e))
        self.counters.record_accepted(len(problems))
        # 6. await results within the remaining budget; on expiry the
        # futures are cancelled so still-queued work is dropped at
        # flush time instead of solved.
        timeout = None
        if budget is not None:
            timeout = budget - (time.perf_counter() - t0)
            if timeout <= 0.0:
                for f in futures:
                    f.cancel()
                raise RpcError(504, "deadline_exceeded",
                               "deadline expired before dispatch")
        gathered = asyncio.gather(
            *[asyncio.wrap_future(f) for f in futures])
        try:
            results = await asyncio.wait_for(gathered, timeout=timeout)
        except asyncio.TimeoutError:
            for f in futures:
                f.cancel()
            raise RpcError(
                504, "deadline_exceeded",
                f"deadline of {budget * 1e3:.0f}ms expired while "
                "solving")
        except asyncio.CancelledError:
            for f in futures:
                f.cancel()
            raise
        except Exception as e:
            self.scheduler.metrics.record_error(
                "rpc_solve", warn=f"serve_lp.rpc: solve failed ({e!r})")
            raise RpcError(500, "solve_failed",
                           "solve failed; details in server logs and "
                           "the repro_serve_errors_total counter")
        body = [{
            "x": [float(r.x[0]), float(r.x[1])],
            "feasible": bool(r.feasible),
            "objective": float(r.objective),
            "m": int(r.m),
            "bucket_m": int(r.bucket_m),
            "batch_size": int(r.batch_size),
            "latency_ms": round(r.latency_s * 1e3, 3),
        } for r in results]
        if is_batch:
            return json_response(200, {"results": body, "n": len(body)})
        return json_response(200, {"result": body[0]})

    # -- observability ----------------------------------------------------

    def _metrics(self) -> Response:
        snap = self.scheduler.metrics.snapshot(
            self.scheduler.cache.stats())
        tracer = self._tracer
        text = render_metrics(
            snap, rpc=self.counters.snapshot(),
            quotas=self.quotas.snapshot(),
            slo=self.slo.plans() if self.slo is not None else None,
            trace=(tracer.stats() if tracer is not None else None))
        return Response(200, text.encode("utf-8"),
                        content_type=CONTENT_TYPE)

    def _debug_trace(self, req: Request) -> Response:
        """The span ring as Chrome trace_event JSON (Perfetto-loadable)
        or raw span dicts (``format=spans``), optionally filtered to
        one trace id."""
        tracer = self._tracer
        if tracer is None or not tracer.enabled:
            return error_response(RpcError(
                404, "tracing_disabled",
                "the scheduler was built without an enabled tracer; "
                "start the server with --trace"))
        spans = tracer.spans()
        trace_id = req.query.get("trace_id")
        if trace_id:
            spans = spans_for_trace(spans, trace_id.strip().lower())
        if req.query.get("format") == "spans":
            return json_response(200, {
                "spans": [s.to_dict() for s in spans],
                "ring": tracer.stats()})
        return json_response(200, to_chrome_trace(spans))

    def _debug_flight(self, req: Request) -> Response:
        """Flight-recorder spool: the index (with recorder stats), or
        one snapshot body via ``?name=``."""
        rec = self._recorder
        if rec is None:
            return error_response(RpcError(
                404, "flight_recorder_disabled",
                "no flight recorder configured; start the server with "
                "--flight-spool"))
        name = req.query.get("name")
        if name:
            snap = rec.load_snapshot(name)
            if snap is None:
                return error_response(RpcError(
                    404, "snapshot_not_found",
                    f"no spool snapshot named {name!r}"))
            return json_response(200, snap)
        return json_response(200, {
            "snapshots": rec.list_snapshots(),
            "recorder": rec.stats()})


# -- the HTTP/1.1 byte layer ----------------------------------------------

async def _read_request(reader: asyncio.StreamReader,
                        body_max: int) -> Optional[Request]:
    """Parse one request off a keep-alive connection; None on clean
    EOF; raises RpcError(400/413) on malformed/oversized input."""
    try:
        line = await reader.readline()
    except ConnectionError:
        return None
    except (ValueError, asyncio.LimitOverrunError):
        # StreamReader.readline reports a line longer than the stream
        # limit as ValueError — answer 400, don't drop the connection
        # with an unhandled task exception.
        raise RpcError(400, "bad_request", "request line too long")
    if not line:
        return None
    if len(line) > _MAX_HEADER_LINE:
        raise RpcError(400, "bad_request", "request line too long")
    try:
        method, path, version = line.decode("ascii").split()
    except ValueError:
        raise RpcError(400, "bad_request",
                       f"malformed request line {line!r}")
    if not version.startswith("HTTP/1."):
        raise RpcError(400, "bad_request",
                       f"unsupported protocol {version!r}")
    headers: Dict[str, str] = {}
    for _ in range(_MAX_HEADERS):
        try:
            line = await reader.readline()
        except (ValueError, asyncio.LimitOverrunError):
            raise RpcError(400, "bad_request", "header line too long")
        if line in (b"\r\n", b"\n", b""):
            break
        if len(line) > _MAX_HEADER_LINE:
            raise RpcError(400, "bad_request", "header line too long")
        name, _, value = line.decode("latin-1").partition(":")
        headers[name.strip().lower()] = value.strip()
    else:
        raise RpcError(400, "bad_request", "too many headers")
    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError:
            raise RpcError(400, "bad_request", "bad Content-Length")
        if n < 0:
            raise RpcError(400, "bad_request", "bad Content-Length")
        if n > body_max:
            raise RpcError(413, "body_too_large",
                           f"request body {n}B exceeds {body_max}B")
        body = await reader.readexactly(n)
    elif headers.get("transfer-encoding"):
        raise RpcError(400, "bad_request",
                       "chunked bodies are not supported; send "
                       "Content-Length")
    path, _, qs = path.partition("?")
    query = dict(urllib.parse.parse_qsl(qs)) if qs else {}
    return Request(method=method.upper(), path=path,
                   headers=headers, body=body, query=query)


class RpcServer:
    """asyncio TCP server wrapping an :class:`LPFrontend`.

    ``await start()`` binds (``port=0`` picks a free port, re-read from
    ``self.port``) and starts the frontend; ``await aclose()`` stops
    accepting, then closes the frontend (final flush + drain).
    """

    def __init__(self, frontend: LPFrontend, host: str = "127.0.0.1",
                 port: int = 0):
        self.frontend = frontend
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "RpcServer":
        self.frontend.start()
        self._server = await asyncio.start_server(
            self._on_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        # Scheduler close blocks on drain — keep it off the loop.
        await asyncio.get_running_loop().run_in_executor(
            None, self.frontend.close)

    async def _on_connection(self, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter) -> None:
        body_max = self.frontend.policy.body_max_bytes
        try:
            while True:
                try:
                    req = await _read_request(reader, body_max)
                except RpcError as e:
                    writer.write(error_response(e).encode(close=True))
                    await writer.drain()
                    break
                except (asyncio.IncompleteReadError, ConnectionError):
                    break
                if req is None:
                    break
                resp = await self.frontend.handle(req)
                close = (req.headers.get("connection", "").lower()
                         == "close")
                writer.write(resp.encode(close=close))
                await writer.drain()
                if close:
                    break
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass


def run_in_thread(frontend: LPFrontend, host: str = "127.0.0.1",
                  port: int = 0) -> Tuple[int, Callable[[], None]]:
    """Run an :class:`RpcServer` on a daemon thread with its own event
    loop; returns ``(bound_port, stop)``.  The bench and the
    real-socket tests use this — production runs ``python -m
    repro.serve_lp.rpc`` (see ``__main__``)."""
    started = threading.Event()
    state: Dict[str, Any] = {}

    async def _main():
        server = RpcServer(frontend, host, port)
        await server.start()
        state["port"] = server.port
        state["loop"] = asyncio.get_running_loop()
        state["stop"] = asyncio.Event()
        started.set()
        try:
            await state["stop"].wait()
        finally:
            await server.aclose()

    def _run():
        try:
            asyncio.run(_main())
        except Exception as e:   # surface bind errors to the waiter
            state["error"] = e
            started.set()

    thread = threading.Thread(target=_run, name="serve-lp-rpc",
                              daemon=True)
    thread.start()
    if not started.wait(timeout=30.0):
        raise RuntimeError("RPC server failed to start within 30s")
    if "error" in state:
        raise state["error"]

    def stop() -> None:
        state["loop"].call_soon_threadsafe(state["stop"].set)
        thread.join(timeout=60.0)

    return state["port"], stop


# -- one-call construction -------------------------------------------------

def make_frontend(spec=None, *,
                  max_batch: int = 256,
                  max_wait_s: float = 0.005,
                  max_inflight: int = 2,
                  pipeline: bool = True,
                  policy: Optional[AdmissionPolicy] = None,
                  quotas: Optional[QuotaManager] = None,
                  target_p99_s: Optional[float] = None,
                  metrics=None,
                  tracer=None,
                  recorder=None) -> LPFrontend:
    """Build scheduler + admission + quota + SLO in one call — the
    shared construction path of ``__main__``, the bench's ``--rpc``
    mode, and tests.  ``tracer``/``recorder`` are handed to the
    scheduler; the frontend picks them up from there."""
    from repro.serve_lp.scheduler import BatchScheduler
    scheduler = BatchScheduler(
        spec, max_batch=max_batch, max_wait_s=max_wait_s,
        max_inflight=max_inflight, pipeline=pipeline, metrics=metrics,
        tracer=tracer, recorder=recorder)
    slo = (SLOController(target_p99_s)
           if target_p99_s is not None else None)
    return LPFrontend(scheduler, policy=policy, quotas=quotas, slo=slo)
