"""Per-tenant token-bucket quotas for the RPC front end.

Every solve request carries a tenant identity (the ``X-Tenant`` header;
absent means the shared ``"anonymous"`` bucket).  Each tenant gets a
classic token bucket: tokens refill continuously at ``rate`` LPs/s up
to a ``burst`` cap, and admitting a request costs one token per LP in
it — so a tenant can burst up to ``burst`` LPs instantly but sustains
only ``rate``.  Rejections are *priced*: :meth:`TokenBucket.try_take`
returns the seconds until enough tokens will have refilled, which the
server surfaces as ``Retry-After`` so well-behaved clients back off by
exactly the right amount instead of hammering.

The clock is injectable (monotonic seconds) so tests drive refill
deterministically without sleeping.  All state is lock-guarded: the
asyncio handler awaits in one thread but the bench and metrics scrape
read counters from others.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Callable, Dict, Optional, Tuple

DEFAULT_TENANT = "anonymous"


class TokenBucket:
    """One tenant's continuously-refilling token bucket."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if not rate > 0.0:
            raise ValueError(f"rate={rate} must be > 0 LPs/s")
        if not burst >= 1.0:
            raise ValueError(f"burst={burst} must be >= 1")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._t_last = clock()

    def _refill(self, now: float) -> None:
        if now > self._t_last:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t_last)
                               * self.rate)
        self._t_last = now

    def try_take(self, cost: float = 1.0) -> float:
        """Admit a request costing ``cost`` tokens.

        Returns 0.0 on admission (tokens deducted).  Otherwise returns
        the seconds until the bucket will hold ``cost`` tokens — no
        deduction — which is the honest ``Retry-After``.  A cost above
        ``burst`` can never be admitted and returns ``inf`` (the caller
        should reject it as oversized rather than retryable).
        """
        if cost > self.burst:
            return math.inf
        self._refill(self._clock())
        if self._tokens >= cost:
            self._tokens -= cost
            return 0.0
        return (cost - self._tokens) / self.rate

    @property
    def tokens(self) -> float:
        self._refill(self._clock())
        return self._tokens


class QuotaManager:
    """Tenant -> bucket map with admission accounting.

    ``per_tenant`` optionally overrides ``(rate, burst)`` for named
    tenants (everyone else gets the defaults); buckets are created
    lazily on first sight of a tenant.  Counters (admitted / rejected
    LPs per tenant) feed the Prometheus exposition.
    """

    def __init__(self, rate: float = 10_000.0, burst: float = 2_000.0,
                 per_tenant: Optional[Dict[str, Tuple[float, float]]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._rate = float(rate)
        self._burst = float(burst)
        self._per_tenant = dict(per_tenant or {})
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        self._lock = threading.Lock()
        self.admitted: Dict[str, int] = {}
        self.rejected: Dict[str, int] = {}

    def _bucket(self, tenant: str) -> TokenBucket:
        bucket = self._buckets.get(tenant)
        if bucket is None:
            rate, burst = self._per_tenant.get(
                tenant, (self._rate, self._burst))
            bucket = self._buckets[tenant] = TokenBucket(
                rate, burst, clock=self._clock)
        return bucket

    def admit(self, tenant: str, cost: float = 1.0) -> float:
        """0.0 = admitted (cost deducted); positive = rejected, retry
        after that many seconds; ``inf`` = never admissible (cost
        exceeds the tenant's burst)."""
        with self._lock:
            retry = self._bucket(tenant).try_take(cost)
            if retry == 0.0:
                self.admitted[tenant] = (self.admitted.get(tenant, 0)
                                         + int(cost))
            else:
                self.rejected[tenant] = (self.rejected.get(tenant, 0)
                                         + int(cost))
            return retry

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant accounting for /metrics."""
        with self._lock:
            tenants = (set(self._buckets) | set(self.admitted)
                       | set(self.rejected))
            return {
                t: {
                    "admitted": self.admitted.get(t, 0),
                    "rejected": self.rejected.get(t, 0),
                    "tokens": (self._buckets[t].tokens
                               if t in self._buckets else 0.0),
                }
                for t in sorted(tenants)
            }
