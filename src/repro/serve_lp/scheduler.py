"""Micro-batching scheduler: per-request submit/future API over the
batch solver.

Requests land in per-``bucket_m`` queues.  A queue flushes when it
reaches ``max_batch`` (size trigger, runs inline on the submitting
thread so a full batch never waits) or when its oldest request exceeds
``max_wait_s`` (wait trigger, run by a background timer thread started
via ``with scheduler:`` or :meth:`start`).  ``flush()`` drains
everything immediately — the deterministic path used by tests and
step-synchronous callers like the crowd simulation.

A flush assembles its super-batch *directly into the packed SoA layout*
the device wants — one host numpy block ``L (b_pad, 4, bucket_m)`` with
``(a_x, a_y, b, 0)`` rows — pads the batch dimension up the geometric
ladder (see ``buckets``), fetches the executable for its
:class:`~repro.serve_lp.buckets.ExecSpec` from the cache, solves, and
resolves each future with an :class:`LPResult` in submission order.
There is no AoS intermediate and no device-side repack: the executable
consumes ``(L, c, mv)`` as assembled (``core.pack_call_count`` stays
flat across flushes).  Solver failures propagate to every future of the
flush via ``set_exception``.

Two per-flush costs are engineered away:

* *launch geometry* — specs with unset ``tile``/``chunk`` are pinned
  **per bucket shape** via
  :meth:`~repro.solver.SolverSpec.resolve_for_shape` (explicit >
  measured tuning table > heuristic), so each bucket's executable runs
  the geometry measured best for its shape class;
* *host allocation* — the packed flush buffers come from a per-bucket
  :class:`_FlushBufferPool` and are reused across flushes (steady-state
  traffic on a stable bucket performs zero buffer allocations; the pool
  counts allocations so tests can assert it).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.lp import PAD_B
from repro.kernels.batch_lp import LANE
from repro.serve_lp.buckets import (ExecSpec, ExecutableCache, bucket_batch,
                                    bucket_m)
from repro.serve_lp.metrics import ServeMetrics
from repro.serve_lp.sharding import build_executable
from repro.solver import SolverSpec

# Serving needs a concrete tile for its b_pad ladder; specs built with
# tile=None and no tuning-table entry for the flush shape get this (the
# historical scheduler default).
DEFAULT_SERVE_TILE = 32


class _FlushBufferPool:
    """Reuse the host-side packed flush buffers across flushes.

    One flush needs ``L (b_pad, 4, bm)``, ``c (b_pad, 2)`` and
    ``mv (b_pad, 1)``; allocating them fresh per flush was the last
    per-flush cost on the serving hot path.  ``lease`` hands out a
    zeroed buffer set for a shape (reusing a previously returned one
    when available — steady-state traffic on a stable bucket allocates
    exactly once) and takes it back afterwards.  Concurrent flushes of
    the same shape (timer thread + inline size trigger) each get their
    own set; at most ``max_per_key`` sets are retained per shape.

    Returning the buffers *after* the executable has run is safe: the
    built executables are synchronous (they return host numpy arrays),
    so the device is done with the transferred inputs by then.
    """

    def __init__(self, max_per_key: int = 2):
        self._free: Dict[tuple, List[tuple]] = {}
        self._lock = threading.Lock()
        self._max_per_key = max_per_key
        self.alloc_count = 0   # fresh allocations (tests assert reuse)
        self.lease_count = 0

    def _take(self, key):
        with self._lock:
            self.lease_count += 1
            stack = self._free.get(key)
            if stack:
                return stack.pop()
        return None

    def _give(self, key, bufs) -> None:
        with self._lock:
            stack = self._free.setdefault(key, [])
            if len(stack) < self._max_per_key:
                stack.append(bufs)

    @contextlib.contextmanager
    def lease(self, b_pad: int, bm: int, dtype: np.dtype):
        key = (b_pad, bm, np.dtype(dtype).str)
        bufs = self._take(key)
        if bufs is None:
            with self._lock:
                self.alloc_count += 1
            bufs = (np.empty((b_pad, 4, bm), dtype),
                    np.empty((b_pad, 2), dtype),
                    np.empty((b_pad, 1), np.int32))
        L, c, mv = bufs
        # Reset to the neutral flush background: padding columns and
        # problems must look exactly like freshly zeroed buffers.
        L.fill(0.0)
        L[:, 2, :] = PAD_B
        c[:, 0] = 1.0
        c[:, 1] = 0.0
        mv.fill(0)
        try:
            yield L, c, mv
        finally:
            self._give(key, bufs)


@dataclasses.dataclass(frozen=True)
class LPResult:
    """Per-request solve result delivered through the future."""

    x: np.ndarray        # (2,) argmax (garbage where infeasible)
    feasible: bool
    objective: float     # c @ x
    m: int               # the request's own constraint count
    bucket_m: int        # shape bucket it was solved in
    batch_size: int      # real requests fused into its flush
    latency_s: float     # submit -> result


@dataclasses.dataclass
class _Pending:
    """One queued request, already split into the packed row layout so
    a flush copies straight into the ``L`` block."""

    ax: np.ndarray       # (m,) constraint normal x-components
    ay: np.ndarray       # (m,) constraint normal y-components
    b: np.ndarray        # (m,) offsets
    c: np.ndarray        # (2,) objective
    m: int
    future: Future
    t_submit: float


class BatchScheduler:
    """Accumulate single 2-D LPs into bucketed super-batches and solve.

    Parameters
    ----------
    spec:
        the :class:`~repro.solver.SolverSpec` every flush solves with.
        It becomes part of each flush's :class:`ExecSpec` cache key, so
        two schedulers with different specs can never alias
        executables.  ``backend="auto"``/``interpret=None`` resolve
        against the running JAX backend at construction (the m-bucket
        ladder depends on the backend, so auto cannot stay
        shape-dependent here — pass an explicit backend to choose);
        ``tile=None``/``chunk=None`` are pinned per bucket shape at
        flush time (measured tuning table first, then the serving
        default tile of 32).
    method, tile, chunk, M, normalize, interpret:
        deprecated flag-bag alternative to ``spec`` (mapped onto an
        equivalent SolverSpec; passing both is an error).
    max_batch:
        size trigger — a bucket flushes as soon as it holds this many.
    max_wait_s:
        wait trigger — no request waits longer than this once the
        background thread is running.
    devices:
        device list to shard flushes over; default ``jax.devices()``.
    """

    def __init__(
        self,
        spec: Optional[SolverSpec] = None,
        *,
        method: Optional[str] = None,
        max_batch: int = 256,
        max_wait_s: float = 0.005,
        tile: Optional[int] = None,
        chunk: Optional[int] = None,
        M: Optional[float] = None,
        normalize: Optional[bool] = None,
        interpret: Optional[bool] = None,
        devices: Optional[Sequence] = None,
        metrics: Optional[ServeMetrics] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} < 1")
        legacy = {k: v for k, v in dict(
            backend=method, tile=tile, chunk=chunk, M=M,
            normalize=normalize, interpret=interpret).items()
            if v is not None}
        if spec is None:
            spec = SolverSpec(**{"backend": "rgb", **legacy})
        elif legacy:
            raise TypeError(
                f"pass either spec= or legacy solver kwargs, not both "
                f"(got {sorted(legacy)})")
        elif not isinstance(spec, SolverSpec):
            raise TypeError(f"spec must be a SolverSpec, got "
                            f"{type(spec)!r}")
        spec = spec.resolve()
        if spec.shuffle:
            # The spec-seeded shuffle permutes the *flushed super-batch*,
            # so a request's constraint order would depend on its row and
            # on b_pad — breaking the guarantee that scheduler round
            # trips are bit-identical to direct solves with the spec.
            raise ValueError(
                "BatchScheduler does not support shuffle=True specs: "
                "per-request results would depend on flush composition; "
                "pre-shuffle requests client-side if randomised order is "
                "needed")
        # tile/chunk left unset stay unset here: they are pinned *per
        # bucket shape* at flush time (resolve_for_shape: explicit >
        # tuning table > heuristic), so different buckets can run the
        # geometry measured best for their shape class.
        self.spec = spec
        # Request buffers are assembled host-side at the solve dtype, so
        # a float64 spec is not silently truncated to float32 on submit.
        # (resolve() above already rejected x64 specs when jax x64 is
        # off, matching the solver's own check.)
        self._dtype = np.dtype(spec.dtype)
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        # Only the Pallas kernel needs LANE-multiple constraint counts;
        # the dense solvers bucket on a finer ladder so tiny LPs are not
        # padded 16x (crowd_sim submits m=8).
        self.bucket_base = LANE if spec.backend == "kernel" else 8
        self._devices = (list(devices) if devices is not None
                         else jax.devices())
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.cache = ExecutableCache(
            lambda s: build_executable(s, self._devices))
        self.buffers = _FlushBufferPool()
        self._queues: Dict[int, List[_Pending]] = {}
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False

    # Legacy attribute views (pre-SolverSpec callers/reporting).
    @property
    def method(self) -> str:
        return self.spec.backend

    @property
    def tile(self) -> int:
        return (self.spec.tile if self.spec.tile is not None
                else DEFAULT_SERVE_TILE)

    @property
    def chunk(self) -> int:
        return 0 if self.spec.chunk is None else self.spec.chunk

    @property
    def M(self) -> float:
        return self.spec.M

    @property
    def normalize(self) -> bool:
        return self.spec.normalize

    @property
    def interpret(self) -> bool:
        return self.spec.interpret

    @property
    def n_devices(self) -> int:
        return len(self._devices)

    @property
    def batch_unit(self) -> int:
        """Fallback flush-padding unit (tile per device).  Buckets whose
        pinned tile differs (tuned entries) pad on their own unit."""
        return self.tile * len(self._devices)

    def _pin_for_bucket(self, bm: int, batch: int) -> SolverSpec:
        """The fully shape-resolved spec one bucket's flush runs with:
        explicit spec values win, then the measured tuning table at
        this bucket's shape class, then the defaults (the dense
        heuristic tile doubles as the historical serving default)."""
        return self.spec.resolve_for_shape(bm, batch)

    # -- submission ------------------------------------------------------

    def submit(self, A, b, c) -> Future:
        """Submit one LP (A (m,2), b (m,), c (2,)); returns a Future
        resolving to :class:`LPResult`.  Buffers are kept at the spec's
        dtype and pre-split into packed rows."""
        dt = self._dtype
        A = np.asarray(A, dt).reshape(-1, 2)
        m = A.shape[0]
        b = np.asarray(b, dt).reshape(m)
        c = np.asarray(c, dt).reshape(2)
        if m < 1:
            raise ValueError("LP needs at least one constraint")
        if self._closed:
            raise RuntimeError("scheduler is closed")
        fut: Future = Future()
        req = _Pending(ax=np.ascontiguousarray(A[:, 0]),
                       ay=np.ascontiguousarray(A[:, 1]),
                       b=b, c=c, m=m, future=fut,
                       t_submit=time.perf_counter())
        bm = bucket_m(m, base=self.bucket_base)
        self.metrics.touch_clock()
        ready = None
        with self._lock:
            q = self._queues.setdefault(bm, [])
            q.append(req)
            if len(q) >= self.max_batch:
                ready = self._queues.pop(bm)
        if ready is not None:
            self._solve(bm, ready, reason="size")
        return fut

    def submit_many(self, As, bs, cs, m_valid=None) -> List[Future]:
        """Row-wise submit of stacked arrays (B, m, 2)/(B, m)/(B, 2);
        ``m_valid`` optionally trims each problem's constraint count."""
        As = np.asarray(As, self._dtype)
        bs = np.asarray(bs, self._dtype)
        cs = np.asarray(cs, self._dtype)
        B = As.shape[0]
        if m_valid is None:
            m_valid = np.full((B,), As.shape[1], np.int32)
        else:
            m_valid = np.asarray(m_valid, np.int32)
        return [self.submit(As[i, :m_valid[i]], bs[i, :m_valid[i]], cs[i])
                for i in range(B)]

    # -- flushing --------------------------------------------------------

    def flush(self) -> int:
        """Drain all buckets now (manual trigger); returns LPs solved."""
        with self._lock:
            drained = [(bm, q) for bm, q in self._queues.items() if q]
            self._queues = {}
        n = 0
        for bm, reqs in drained:
            self._solve(bm, reqs, reason="manual")
            n += len(reqs)
        return n

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def _flush_expired(self) -> None:
        now = time.perf_counter()
        with self._lock:
            expired = [
                (bm, q) for bm, q in self._queues.items()
                if q and now - q[0].t_submit >= self.max_wait_s]
            for bm, _ in expired:
                self._queues.pop(bm)
        for bm, reqs in expired:
            self._solve(bm, reqs, reason="wait")

    # -- background wait-trigger thread ----------------------------------

    def start(self) -> "BatchScheduler":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._timer_loop, name="serve-lp-flush", daemon=True)
            self._thread.start()
        return self

    def stop(self, *, final_flush: bool = True) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None
        if final_flush:
            self.flush()

    def __enter__(self) -> "BatchScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def close(self) -> None:
        self.stop()
        self._closed = True

    def _timer_loop(self) -> None:
        tick = max(self.max_wait_s / 4.0, 1e-4)
        while not self._stop.wait(tick):
            try:
                self._flush_expired()
            except Exception:
                # The flush's futures already carry the exception; the
                # timer must survive so later buckets still get flushed.
                pass

    # -- the solve path --------------------------------------------------

    def _solve(self, bm: int, reqs: List[_Pending], *, reason: str) -> None:
        B = len(reqs)
        pinned = self._pin_for_bucket(bm, B)
        b_pad = bucket_batch(B, pinned.tile * len(self._devices))
        # Host-side numpy twin of core.packed: the flush is assembled
        # *directly* into the packed (b_pad, 4, bm) block — neutral
        # columns/problems are a_x = a_y = 0, b = PAD_B, c = (1, 0),
        # m_valid = 0 — so the executable consumes it as-is: no AoS
        # intermediate, no device-side re-stack.  The buffers are
        # leased from the per-bucket pool (reused across flushes).
        spec = ExecSpec(bucket_m=bm, b_pad=b_pad, solver=pinned,
                        n_devices=len(self._devices))
        try:
            with self.buffers.lease(b_pad, bm, self._dtype) as (L, c, mv):
                for i, r in enumerate(reqs):
                    L[i, 0, :r.m] = r.ax
                    L[i, 1, :r.m] = r.ay
                    L[i, 2, :r.m] = r.b
                    c[i] = r.c
                    mv[i, 0] = r.m
                fn = self.cache.get(spec)
                t0 = time.perf_counter()
                x, feas = fn(L, c, mv)
                dt_solve = time.perf_counter() - t0
        except Exception as e:  # propagate to every waiter, don't hang
            for r in reqs:
                r.future.set_exception(e)
            raise
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            xi = np.asarray(x[i])
            r.future.set_result(LPResult(
                x=xi,
                feasible=bool(feas[i]),
                objective=float(r.c @ xi),
                m=r.m,
                bucket_m=bm,
                batch_size=B,
                latency_s=now - r.t_submit,
            ))
            self.metrics.record_latency(now - r.t_submit)
        self.metrics.record_flush(
            n_real=B, b_pad=b_pad, bucket_m=bm,
            sum_m=sum(r.m for r in reqs), solve_seconds=dt_solve,
            reason=reason)
