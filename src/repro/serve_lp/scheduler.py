"""Micro-batching scheduler: per-request submit/future API over the
batch solver.

Requests land in per-``bucket_m`` queues.  A queue flushes when it
reaches ``max_batch`` (size trigger, runs inline on the submitting
thread so a full batch never waits) or when its oldest request exceeds
``max_wait_s`` (wait trigger, run by a background timer thread started
via ``with scheduler:`` or :meth:`start`).  ``flush()`` drains
everything immediately — the deterministic path used by tests and
step-synchronous callers like the crowd simulation.

The serve loop is *pipelined*: a flush is three named stages instead of
one blocking call —

* **assemble** (:meth:`BatchScheduler._assemble`, on the flushing
  thread) — lease packed host buffers from the per-bucket
  :class:`_FlushBufferPool`, fill them directly in the SoA layout the
  device wants (one block ``L (b_pad, 4, bucket_m)`` with
  ``(a_x, a_y, b, 0)`` rows; no AoS intermediate, no device-side
  repack — ``core.pack_call_count`` stays flat), and fetch the cached
  :class:`~repro.serve_lp.sharding.Executable` for the flush's
  :class:`~repro.serve_lp.buckets.ExecSpec`;
* **dispatch** (:meth:`BatchScheduler._dispatch`, same thread) — hand
  the buffers to ``Executable.dispatch`` (async: returns device-array
  handles without synchronizing) and enqueue an :class:`_InflightFlush`
  work unit.  Dispatch blocks while ``max_inflight`` flushes are
  already in flight (backpressure), which is what bounds device queue
  depth and lets the *next* flush's assembly overlap the in-flight
  solve;
* **complete** (the ``serve-lp-complete`` worker thread) — block on the
  handles (``Executable.complete``), return the leased buffers to the
  pool, record metrics, and scatter an :class:`LPResult` into every
  future in submission order.  ``_InflightFlush.done`` is the explicit
  per-unit join point; :meth:`drain` joins all of them.

``pipeline=False`` restores the stop-and-go loop (the three stages run
back-to-back on the flushing thread), which is still what you want for
strictly step-synchronous callers that flush and immediately wait.

Flushes shard over devices per the scheduler's ``sharding`` mode:
``"mesh"`` (default) plans a
:class:`~repro.serve_lp.mesh_layout.MeshLayout` per flush — uneven
per-device shards, planner-owned padding (the batch ladder unit is one
kernel ``tile``, not ``tile * n_devices``) and grouped ``shard_map``
launches; ``"pmap"`` is the legacy even-split escape hatch.  Mesh mode
also enables **cross-bucket fusing** (``fuse=True``): buckets whose
queues are individually under the size trigger but jointly fill a
launch are drained into one *fused flush unit* — their requests packed
into a single super-batch padded to the largest member's ``m_pad``
(still a ladder value, so fused flushes reuse the same cached
executables), solved in one launch, and scattered back to each
request's own future.  Fusing fires on the submit path (joint-fill
trigger, reason ``"fused"``), in the wait-trigger sweep, and on manual
:meth:`flush`; the SLO controller can veto it per bucket via the
3-tuple bucket-policy form.

Failure discipline: a solve failure reaches every future of *its own*
flush via ``set_exception`` and never orphans another bucket — manual
and expired flushes isolate per-bucket errors and re-raise the first
one only after every drained bucket has been dispatched.  Completion
failures land on the flush's futures and in the
``ServeMetrics`` error counters (never silently swallowed).

Two per-flush costs are engineered away:

* *launch geometry* — specs with unset ``tile``/``chunk`` are pinned
  **per bucket shape** via
  :meth:`~repro.solver.SolverSpec.resolve_for_shape` (explicit >
  measured tuning table > heuristic), so each bucket's executable runs
  the geometry measured best for its shape class;
* *host allocation* — the packed flush buffers come from a per-bucket
  :class:`_FlushBufferPool` and are reused across flushes (steady-state
  traffic on a stable bucket performs zero buffer allocations; the pool
  counts allocations so tests can assert it).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.lp import PAD_B
from repro.kernels.batch_lp import LANE
from repro.obs.profiler import annotation as _device_annotation
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import (NOOP_TRACER, TraceContext, Tracer,
                             new_trace_context)
from repro.serve_lp.buckets import (SHARDING_MODES, ExecSpec,
                                    ExecutableCache, bucket_batch, bucket_m)
from repro.serve_lp.metrics import ServeMetrics
from repro.serve_lp.sharding import as_executable, build_executable
from repro.solver import SolverSpec

# Serving needs a concrete tile for its b_pad ladder; specs built with
# tile=None and no tuning-table entry for the flush shape get this (the
# historical scheduler default).
DEFAULT_SERVE_TILE = 32

# Default bound on concurrently in-flight flushes: two is enough to
# overlap assembly with an in-flight solve without letting the device
# queue (and tail latency) grow unboundedly.
DEFAULT_MAX_INFLIGHT = 2


def _try_set_result(fut: Future, value: Any) -> bool:
    """``fut.set_result(value)``, tolerating a concurrent cancel.

    The RPC layer cancels futures from the asyncio thread on deadline
    expiry while flush threads settle them; a ``done()`` pre-check only
    narrows that window.  Losing the race must skip *one* future — an
    ``InvalidStateError`` escaping here would abort the completion
    scatter mid-flush and orphan every later future of the flush."""
    if fut.done():
        return False
    try:
        fut.set_result(value)
        return True
    except InvalidStateError:
        return False


def _try_set_exception(fut: Future, exc: BaseException) -> bool:
    """``fut.set_exception(exc)`` with the same race tolerance as
    :func:`_try_set_result`."""
    if fut.done():
        return False
    try:
        fut.set_exception(exc)
        return True
    except InvalidStateError:
        return False


class _FlushBufferPool:
    """Reuse the host-side packed flush buffers across flushes.

    One flush needs ``L (b_pad, 4, bm)``, ``c (b_pad, 2)`` and
    ``mv (b_pad, 1)``; allocating them fresh per flush was the last
    per-flush cost on the serving hot path.  ``lease`` hands out a
    zeroed buffer set for a shape (reusing a previously returned one
    when available — steady-state traffic on a stable bucket allocates
    exactly once); ``release`` takes it back.  Concurrent flushes of
    the same shape (pipelined in-flight flushes, timer thread + inline
    size trigger) each get their own set; at most ``max_per_key`` sets
    are retained per shape.

    **Lifetime contract (pipelined serve loop).**  A leased buffer set
    stays leased until its flush *completes*, not merely until dispatch
    returns: dispatch is asynchronous, so the device-side transfer of
    the host buffers may still be in progress (or pending) when the
    dispatching thread moves on.  Only the completion stage — after
    ``Executable.complete`` has synchronized with the device — may call
    :meth:`release`.  (The pre-pipelining context-manager lease that
    returned buffers in a ``finally`` right after the solve call was
    only sound while executables were synchronous.)
    """

    def __init__(self, max_per_key: int = 2):
        self._free: Dict[tuple, List[tuple]] = {}
        self._lock = threading.Lock()
        self._max_per_key = max_per_key
        self.alloc_count = 0   # fresh allocations (tests assert reuse)
        self.lease_count = 0
        self.release_count = 0  # lease_count - release_count = leased now

    def lease(self, b_pad: int, bm: int, dtype: np.dtype
              ) -> Tuple[tuple, tuple]:
        """Lease an initialized ``(L, c, mv)`` set for one flush shape;
        returns ``(key, bufs)`` — pass both back to :meth:`release`
        when (and only when) the flush has completed."""
        key = (b_pad, bm, np.dtype(dtype).str)
        with self._lock:
            self.lease_count += 1
            stack = self._free.get(key)
            bufs = stack.pop() if stack else None
            if bufs is None:
                self.alloc_count += 1
        if bufs is None:
            bufs = (np.empty((b_pad, 4, bm), dtype),
                    np.empty((b_pad, 2), dtype),
                    np.empty((b_pad, 1), np.int32))
        L, c, mv = bufs
        # Reset to the neutral flush background: padding columns and
        # problems must look exactly like freshly zeroed buffers.
        L.fill(0.0)
        L[:, 2, :] = PAD_B
        c[:, 0] = 1.0
        c[:, 1] = 0.0
        mv.fill(0)
        return key, bufs

    def release(self, key: tuple, bufs: tuple) -> None:
        """Return a leased set once its flush has fully completed."""
        with self._lock:
            self.release_count += 1
            stack = self._free.setdefault(key, [])
            if len(stack) < self._max_per_key:
                stack.append(bufs)


@dataclasses.dataclass(frozen=True)
class LPResult:
    """Per-request solve result delivered through the future."""

    x: np.ndarray        # (2,) argmax (garbage where infeasible)
    feasible: bool
    objective: float     # c @ x
    m: int               # the request's own constraint count
    bucket_m: int        # shape bucket it was solved in
    batch_size: int      # real requests fused into its flush
    latency_s: float     # submit -> result


@dataclasses.dataclass
class _Pending:
    """One queued request, already split into the packed row layout so
    a flush copies straight into the ``L`` block."""

    ax: np.ndarray       # (m,) constraint normal x-components
    ay: np.ndarray       # (m,) constraint normal y-components
    b: np.ndarray        # (m,) offsets
    c: np.ndarray        # (2,) objective
    m: int
    future: Future
    t_submit: float
    # Tracing (None when the scheduler's tracer is disabled): the
    # request's context, its open "request" span, and its open
    # "queue.wait" span.  Open spans are nulled once ended so no path
    # can commit one to the ring twice.
    trace: Optional[TraceContext] = None
    span: Any = None
    qspan: Any = None


@dataclasses.dataclass
class _InflightFlush:
    """One named in-flight flush work unit: everything the completion
    stage needs to finish a dispatched solve — the leased host buffers
    (returned to the pool only here), the device result handles, the
    futures to scatter into, and the stage timestamps the metrics
    report.  ``done`` is the unit's explicit join point (:meth:`
    BatchScheduler.drain` joins all units via the in-flight gauge)."""

    name: str                    # "flush-<seq> m<bucket>xb<b_pad>"
    bucket_m: int
    b_pad: int
    reqs: List[_Pending]
    reason: str
    exe: Any                     # dispatch/complete executable
    buf_key: tuple               # pool lease (returned at completion)
    bufs: tuple                  # (L, c, mv) host arrays
    t_assemble: float            # assembly start
    n_buckets: int = 1           # m-buckets fused into this unit
    t_dispatch: float = 0.0      # dispatch enqueued (device handed work)
    t_complete: float = 0.0      # device results materialized on host
    handle: Any = None           # in-flight device result handle
    counted: bool = False        # holds an in-flight slot (pipelined)
    # Tracing: flush-plane spans are emitted once per flush under the
    # *primary* trace (the first member request's); membership of every
    # fused-in trace rides on the flush.assemble span's trace_ids attr.
    trace_id: Optional[str] = None
    asm_span: Any = None         # the flush.assemble span (parent link)
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)


class BatchScheduler:
    """Accumulate single 2-D LPs into bucketed super-batches and solve.

    Parameters
    ----------
    spec:
        the :class:`~repro.solver.SolverSpec` every flush solves with.
        It becomes part of each flush's :class:`ExecSpec` cache key, so
        two schedulers with different specs can never alias
        executables.  ``backend="auto"``/``interpret=None`` resolve
        against the running JAX backend at construction (the m-bucket
        ladder depends on the backend, so auto cannot stay
        shape-dependent here — pass an explicit backend to choose);
        ``tile=None``/``chunk=None`` are pinned per bucket shape at
        flush time (measured tuning table first, then the serving
        default tile of 32).
    method, tile, chunk, M, normalize, interpret:
        deprecated flag-bag alternative to ``spec`` (mapped onto an
        equivalent SolverSpec; passing both is an error).
    max_batch:
        size trigger — a bucket flushes as soon as it holds this many.
    max_wait_s:
        wait trigger — no request waits longer than this once the
        background thread is running.
    pipeline:
        overlap flush assembly with in-flight solves (default).  A
        flush's dispatch returns without synchronizing and a completion
        worker scatters results; ``False`` restores the stop-and-go
        loop where each flush blocks until its results are scattered.
    max_inflight:
        backpressure bound — a new dispatch blocks while this many
        flushes are already in flight (pipelined mode only).
    devices:
        device list to shard flushes over; default ``jax.devices()``.
    sharding:
        flush-sharding mode — ``"mesh"`` (MeshLayout planner +
        shard_map; uneven shards, planner-owned padding) or ``"pmap"``
        (legacy even-split escape hatch, kept one release).
    fuse:
        enable cross-bucket fused flush units.  Defaults to ``True``
        under mesh sharding and ``False`` under pmap (whose fixed
        even-split geometry predates fused units).
    fuse_max_m_ratio:
        never fuse buckets whose ``m_pad`` differ by more than this
        factor — fusing an m=8 bucket into an m=4096 flush would burn
        more pad cells than the saved launch is worth.
    tracer:
        a :class:`repro.obs.Tracer` to emit typed spans into (request,
        queue.wait, flush.assemble/dispatch/scatter, device.solve per
        launch group).  Default is the shared disabled tracer — the
        untraced hot path costs one no-op counter bump per call site
        and records zero spans.
    recorder:
        a :class:`repro.obs.FlightRecorder`; when given, the scheduler
        binds :meth:`debug_state` as its state source, shares its
        tracer, and wires ``ServeMetrics.record_error`` plus a
        debounced post-flush p99 check to its triggers.
    """

    def __init__(
        self,
        spec: Optional[SolverSpec] = None,
        *,
        method: Optional[str] = None,
        max_batch: int = 256,
        max_wait_s: float = 0.005,
        tile: Optional[int] = None,
        chunk: Optional[int] = None,
        M: Optional[float] = None,
        normalize: Optional[bool] = None,
        interpret: Optional[bool] = None,
        pipeline: bool = True,
        max_inflight: int = DEFAULT_MAX_INFLIGHT,
        devices: Optional[Sequence] = None,
        metrics: Optional[ServeMetrics] = None,
        sharding: str = "mesh",
        fuse: Optional[bool] = None,
        fuse_max_m_ratio: float = 8.0,
        tracer: Optional[Tracer] = None,
        recorder: Optional[FlightRecorder] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} < 1")
        if max_inflight < 1:
            raise ValueError(f"max_inflight={max_inflight} < 1")
        if sharding not in SHARDING_MODES:
            raise ValueError(
                f"sharding={sharding!r} not in {SHARDING_MODES}")
        if fuse_max_m_ratio < 1:
            raise ValueError(
                f"fuse_max_m_ratio={fuse_max_m_ratio} < 1")
        legacy = {k: v for k, v in dict(
            backend=method, tile=tile, chunk=chunk, M=M,
            normalize=normalize, interpret=interpret).items()
            if v is not None}
        if spec is None:
            spec = SolverSpec(**{"backend": "rgb", **legacy})
        elif legacy:
            raise TypeError(
                f"pass either spec= or legacy solver kwargs, not both "
                f"(got {sorted(legacy)})")
        elif not isinstance(spec, SolverSpec):
            raise TypeError(f"spec must be a SolverSpec, got "
                            f"{type(spec)!r}")
        spec = spec.resolve()
        if spec.shuffle:
            # The spec-seeded shuffle permutes the *flushed super-batch*,
            # so a request's constraint order would depend on its row and
            # on b_pad — breaking the guarantee that scheduler round
            # trips are bit-identical to direct solves with the spec.
            raise ValueError(
                "BatchScheduler does not support shuffle=True specs: "
                "per-request results would depend on flush composition; "
                "pre-shuffle requests client-side if randomised order is "
                "needed")
        # tile/chunk left unset stay unset here: they are pinned *per
        # bucket shape* at flush time (resolve_for_shape: explicit >
        # tuning table > heuristic), so different buckets can run the
        # geometry measured best for their shape class.
        self.spec = spec
        # Request buffers are assembled host-side at the solve dtype, so
        # a float64 spec is not silently truncated to float32 on submit.
        # (resolve() above already rejected x64 specs when jax x64 is
        # off, matching the solver's own check.)
        self._dtype = np.dtype(spec.dtype)
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.pipeline = bool(pipeline)
        self.max_inflight = max_inflight
        self.sharding = sharding
        self.fuse = (sharding == "mesh") if fuse is None else bool(fuse)
        self.fuse_max_m_ratio = float(fuse_max_m_ratio)
        # Only the Pallas kernel needs LANE-multiple constraint counts;
        # the dense solvers bucket on a finer ladder so tiny LPs are not
        # padded 16x (crowd_sim submits m=8).
        self.bucket_base = LANE if spec.backend == "kernel" else 8
        self._devices = (list(devices) if devices is not None
                         else jax.devices())
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        if self.tracer.annotate_device:
            # Also label each mesh launch group inside dispatch, so an
            # active jax profiler session shows per-launch regions that
            # match the host device.solve spans.
            from repro.serve_lp import sharding as _sharding_mod
            _sharding_mod.set_launch_annotations(True)
        self.recorder = recorder
        if recorder is not None:
            recorder.bind_state(self.debug_state)
            if recorder.tracer is None:
                recorder.tracer = self.tracer
            self.metrics.set_error_hook(recorder.on_error)
        self.cache = ExecutableCache(
            lambda s: build_executable(s, self._devices))
        self.buffers = _FlushBufferPool()
        self._queues: Dict[int, List[_Pending]] = {}
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False
        # Pipelined-flush state: the in-flight gauge (guarded by its
        # condition variable — dispatch backpressure and drain() both
        # wait on it), the completion work queue, and the lazily
        # started completion worker.  `_active` counts flushes in *any*
        # stage (assemble included, reserved while the queue pop is
        # still lock-held), which is what makes drain() a real join —
        # `_inflight` alone would miss a flush between pop and
        # dispatch.
        self._active = 0
        self._inflight = 0
        self._inflight_cv = threading.Condition()
        self._work_q: "queue.Queue[Optional[_InflightFlush]]" = \
            queue.Queue()
        self._completer: Optional[threading.Thread] = None
        self._flush_seq = 0
        # Optional per-bucket (max_batch, max_wait_s) override hook —
        # installed by the SLO controller so different m-buckets can
        # run different batching limits (a big-m flush takes longer, so
        # holding a p99 target means batching it less / flushing it
        # sooner).  None falls back to the scheduler-wide limits.
        self._bucket_policy: Optional[Any] = None

    # Legacy attribute views (pre-SolverSpec callers/reporting).
    @property
    def method(self) -> str:
        return self.spec.backend

    @property
    def tile(self) -> int:
        return (self.spec.tile if self.spec.tile is not None
                else DEFAULT_SERVE_TILE)

    @property
    def chunk(self) -> int:
        return 0 if self.spec.chunk is None else self.spec.chunk

    @property
    def M(self) -> float:
        return self.spec.M

    @property
    def normalize(self) -> bool:
        return self.spec.normalize

    @property
    def interpret(self) -> bool:
        return self.spec.interpret

    @property
    def n_devices(self) -> int:
        return len(self._devices)

    @property
    def batch_unit(self) -> int:
        """Fallback flush-padding unit.  Mesh sharding pads to whole
        kernel tiles only (the MeshLayout planner owns the per-device
        distribution); legacy pmap needs a whole tile per device.
        Buckets whose pinned tile differs (tuned entries) pad on their
        own unit."""
        return self._unit_for_tile(self.tile)

    def _unit_for_tile(self, tile: int) -> int:
        if self.sharding == "pmap":
            return tile * len(self._devices)
        return tile

    @property
    def inflight(self) -> int:
        """Flushes currently dispatched but not yet completed."""
        with self._inflight_cv:
            return self._inflight

    @property
    def closed(self) -> bool:
        """True once :meth:`close` has begun (submissions refused)."""
        with self._lock:
            return self._closed

    def set_bucket_policy(
            self, policy: Optional[Any]) -> None:
        """Install (or clear) a per-bucket limits hook.

        ``policy(bucket_m)`` returns ``(max_batch, max_wait_s)`` or
        ``(max_batch, max_wait_s, allow_fuse)`` for that m-bucket, or
        ``None`` to fall back to the scheduler-wide limits.  The hook
        is consulted on the submit path (size trigger), by the
        wait-trigger sweep, and — via the optional third element — by
        the cross-bucket fuse planner (``allow_fuse=False`` keeps the
        bucket out of fused flush units).  The timer *tick* still
        derives from the scheduler-wide ``max_wait_s``, so callers
        installing shorter per-bucket waits should also lower that
        (the SLO controller does)."""
        self._bucket_policy = policy

    def _policy_for(self, bm: int) -> Optional[tuple]:
        """The raw policy tuple for one bucket, or None.  A broken
        policy must never take the serve loop down — it is counted and
        the globals apply."""
        policy = self._bucket_policy
        if policy is None:
            return None
        try:
            return policy(bm)
        except Exception as e:
            self.metrics.record_error(
                "bucket_policy",
                warn=f"serve_lp: bucket policy failed for "
                     f"bucket_m={bm} ({e!r}); using scheduler-wide "
                     "limits")
            return None

    def _limits_for(self, bm: int) -> Tuple[int, float]:
        """Effective (max_batch, max_wait_s) for one bucket: the policy
        hook when installed and opinionated, else the globals."""
        lim = self._policy_for(bm)
        if lim is not None:
            mb, mw = lim[0], lim[1]
            return max(1, int(mb)), float(mw)
        return self.max_batch, self.max_wait_s

    def _fuse_ok(self, bm: int) -> bool:
        """Whether the bucket policy allows this bucket in fused flush
        units (the optional third policy element; default yes)."""
        lim = self._policy_for(bm)
        if lim is None or len(lim) < 3:
            return True
        return bool(lim[2])

    def queue_age_s(self, now: Optional[float] = None) -> float:
        """Age of the oldest queued (not yet flushed) request, seconds;
        0.0 when every queue is empty.  The RPC admission layer sheds
        load on this — a growing oldest-age means flushes are not
        keeping up with arrivals."""
        if now is None:
            now = time.perf_counter()
        with self._lock:
            oldest = min((q[0].t_submit for q in self._queues.values()
                          if q), default=None)
        return 0.0 if oldest is None else max(0.0, now - oldest)

    def debug_state(self) -> Dict[str, Any]:
        """One JSON-serializable picture of the scheduler right now —
        what the flight recorder snapshots next to the span ring: queue
        depths per bucket, pipeline counters, buffer-pool leases, and
        the full metrics snapshot (per-device row counts included)."""
        now = time.perf_counter()
        with self._lock:
            queues = {int(bm): len(q)
                      for bm, q in self._queues.items() if q}
            oldest = min((q[0].t_submit
                          for q in self._queues.values() if q),
                         default=None)
            closed = self._closed
        with self._inflight_cv:
            active = self._active
            inflight = self._inflight
        bp = self.buffers
        return {
            "queues": queues,
            "pending": sum(queues.values()),
            "queue_age_s": (0.0 if oldest is None
                            else max(0.0, now - oldest)),
            "closed": closed,
            "active_flushes": active,
            "inflight": inflight,
            "max_inflight": self.max_inflight,
            "pipeline": self.pipeline,
            "sharding": self.sharding,
            "fuse": self.fuse,
            "n_devices": len(self._devices),
            "buffer_pool": {
                "alloc_count": bp.alloc_count,
                "lease_count": bp.lease_count,
                "release_count": bp.release_count,
                "leased_now": bp.lease_count - bp.release_count,
            },
            "metrics": self.metrics.snapshot(self.cache.stats()),
        }

    def _pin_for_bucket(self, bm: int, batch: int) -> SolverSpec:
        """The fully shape-resolved spec one bucket's flush runs with:
        explicit spec values win, then the measured tuning table at
        this bucket's shape class, then the defaults (the dense
        heuristic tile doubles as the historical serving default)."""
        return self.spec.resolve_for_shape(bm, batch)

    # -- submission ------------------------------------------------------

    def submit(self, A, b, c, *,
               trace: Optional[TraceContext] = None) -> Future:
        """Submit one LP (A (m,2), b (m,), c (2,)); returns a Future
        resolving to :class:`LPResult`.  Buffers are kept at the spec's
        dtype and pre-split into packed rows.

        ``trace`` propagates an upstream :class:`TraceContext` (the RPC
        layer's parsed ``X-Trace-Id``); when the scheduler's tracer is
        enabled and none is given, a fresh root context is generated
        here, so every traced request has a full span chain either
        way."""
        dt = self._dtype
        A = np.asarray(A, dt).reshape(-1, 2)
        m = A.shape[0]
        b = np.asarray(b, dt).reshape(m)
        c = np.asarray(c, dt).reshape(2)
        if m < 1:
            raise ValueError("LP needs at least one constraint")
        fut: Future = Future()
        req = _Pending(ax=np.ascontiguousarray(A[:, 0]),
                       ay=np.ascontiguousarray(A[:, 1]),
                       b=b, c=c, m=m, future=fut,
                       t_submit=time.perf_counter())
        bm = bucket_m(m, base=self.bucket_base)
        tracer = self.tracer
        if tracer.enabled:
            ctx = trace if trace is not None else new_trace_context()
            req.trace = ctx
            req.span = tracer.start_span(
                "request", ctx.trace_id, parent_id=ctx.span_id,
                t_start=req.t_submit, bucket_m=bm, m=m)
            req.qspan = tracer.start_span(
                "queue.wait", ctx.trace_id,
                parent_id=req.span.span_id,
                t_start=req.t_submit, bucket_m=bm)
        self.metrics.touch_clock()
        ready = None
        fused = None
        with self._lock:
            # Closed-ness is decided under the same lock close() takes
            # *before* its final flush: a submit either loses the race
            # (raises here) or its request is visible to that flush —
            # no request can slip in after the final flush with no
            # timer thread left to serve it.
            if self._closed:
                raise RuntimeError("scheduler is closed")
            q = self._queues.setdefault(bm, [])
            q.append(req)
            if len(q) >= self._limits_for(bm)[0]:
                ready = self._queues.pop(bm)
                # Reserve the flush in the active count while the pop
                # is still lock-held, so a concurrent close()'s drain
                # cannot slip between pop and dispatch and miss it.
                with self._inflight_cv:
                    self._active += 1
            elif self.fuse:
                fused = self._pop_fused_locked()
                if fused is not None:
                    with self._inflight_cv:
                        self._active += 1
        if ready is not None:
            self._solve(bm, ready, reason="size", pre_counted=True)
        elif fused is not None:
            self._solve_unit(fused, reason="fused", pre_counted=True)
        return fut

    def _pop_fused_locked(self) -> Optional[List[Tuple[int, list]]]:
        """Joint-fill fuse trigger (call with ``_lock`` held): when
        several buckets are each under their size trigger but together
        fill a launch, pop them as one fused flush unit.

        Returns the popped ``[(bucket_m, reqs), ...]`` parts, or None
        when no fusable group of >= 2 buckets reaches ``max_batch``
        rows.  Grouping mirrors :meth:`_plan_units`: buckets sorted by
        ``m_pad``, split where the spread exceeds ``fuse_max_m_ratio``.
        """
        total = sum(len(q) for q in self._queues.values())
        if total < self.max_batch:
            return None
        cands = sorted(
            ((b, q) for b, q in self._queues.items()
             if q and self._fuse_ok(b)),
            key=lambda t: t[0])
        if len(cands) < 2:
            return None
        best: List[Tuple[int, list]] = []
        best_rows = 0
        cur: List[Tuple[int, list]] = []
        cur_rows = 0
        for b, q in cands:
            if cur and b > cur[0][0] * self.fuse_max_m_ratio:
                cur, cur_rows = [], 0
            cur.append((b, q))
            cur_rows += len(q)
            if len(cur) >= 2 and cur_rows > best_rows:
                best, best_rows = list(cur), cur_rows
        if best_rows < self.max_batch:
            return None
        for b, _ in best:
            self._queues.pop(b)
        return best

    def submit_many(self, As, bs, cs, m_valid=None) -> List[Future]:
        """Row-wise submit of stacked arrays (B, m, 2)/(B, m)/(B, 2);
        ``m_valid`` optionally trims each problem's constraint count."""
        As = np.asarray(As, self._dtype)
        bs = np.asarray(bs, self._dtype)
        cs = np.asarray(cs, self._dtype)
        B = As.shape[0]
        if m_valid is None:
            m_valid = np.full((B,), As.shape[1], np.int32)
        else:
            m_valid = np.asarray(m_valid, np.int32)
        return [self.submit(As[i, :m_valid[i]], bs[i, :m_valid[i]], cs[i])
                for i in range(B)]

    # -- flushing --------------------------------------------------------

    def flush(self) -> int:
        """Drain all buckets now (manual trigger); returns LPs solved
        (dispatched — use :meth:`drain` or the futures to wait for
        completion in pipelined mode).

        One unit's failure never orphans another's futures: every
        drained unit is dispatched regardless, each failure lands on
        its own flush's futures, and the first error is re-raised only
        after the loop.
        """
        with self._lock:
            drained = [(bm, q) for bm, q in self._queues.items() if q]
            self._queues = {}
        return self._solve_drained(drained, reason="manual")

    def _solve_drained(self, drained: List[Tuple[int, list]], *,
                       reason: str) -> int:
        """Dispatch already-popped buckets as flush units (fused where
        the planner allows), isolating per-unit errors."""
        n = 0
        first_err: Optional[BaseException] = None
        for parts in self._plan_units(drained):
            try:
                self._solve_unit(
                    parts,
                    reason="fused" if len(parts) > 1 else reason)
            except Exception as e:
                if first_err is None:
                    first_err = e
            n += sum(len(q) for _, q in parts)
        if first_err is not None:
            raise first_err
        return n

    def _plan_units(self, drained: List[Tuple[int, list]]
                    ) -> List[List[Tuple[int, list]]]:
        """Partition drained buckets into flush units.

        With fusing off (or one bucket) every bucket is its own unit —
        the pre-mesh behaviour.  Otherwise buckets that are underfull
        *and* policy-fusable are sorted by ``m_pad`` and greedily
        packed into fused units, closing a unit when the m-spread
        would exceed ``fuse_max_m_ratio`` (pad-cell waste) or the row
        count would exceed ``max_batch`` (keeps fused ``b_pad`` on the
        same ladder rungs normal flushes compile)."""
        if not self.fuse or len(drained) < 2:
            return [[(bm, q)] for bm, q in drained]
        singles: List[List[Tuple[int, list]]] = []
        cands: List[Tuple[int, list]] = []
        for bm, q in drained:
            if len(q) >= self._limits_for(bm)[0] or not self._fuse_ok(bm):
                singles.append([(bm, q)])
            else:
                cands.append((bm, q))
        cands.sort(key=lambda t: t[0])
        units: List[List[Tuple[int, list]]] = []
        cur: List[Tuple[int, list]] = []
        cur_rows = 0
        for bm, q in cands:
            if cur and (bm > cur[0][0] * self.fuse_max_m_ratio
                        or cur_rows + len(q) > self.max_batch):
                units.append(cur)
                cur, cur_rows = [], 0
            cur.append((bm, q))
            cur_rows += len(q)
        if cur:
            units.append(cur)
        return singles + units

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def _flush_expired(self) -> None:
        now = time.perf_counter()
        with self._lock:
            expired = [
                (bm, q) for bm, q in self._queues.items()
                if q and now - q[0].t_submit >= self._limits_for(bm)[1]]
            for bm, _ in expired:
                self._queues.pop(bm)
        # Expired buckets fuse with each other when the planner allows:
        # wait-triggered flushes are underfull by definition, the exact
        # case fused units exist for.
        self._solve_drained(expired, reason="wait")

    # -- background wait-trigger thread ----------------------------------

    def start(self) -> "BatchScheduler":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._timer_loop, name="serve-lp-flush", daemon=True)
            self._thread.start()
        return self

    def stop(self, *, final_flush: bool = True) -> None:
        """Stop the timer thread, optionally flush the tail, and join
        every in-flight flush (quiescent on return).

        A drain that times out is surfaced (not swallowed): it is
        counted as a ``drain_timeout`` error in :class:`ServeMetrics`
        and warned once — callers that need the boolean call
        :meth:`drain` themselves."""
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None
        if final_flush:
            self.flush()
        if not self.drain():
            self.metrics.record_error(
                "drain_timeout",
                warn="serve_lp: stop() timed out draining in-flight "
                     "flushes; some futures may still be pending "
                     "(counted in ServeMetrics errors)")

    def drain(self, timeout: Optional[float] = 600.0) -> bool:
        """Join point: block until every flush in any stage (assemble,
        dispatch, in flight) has completed or failed.  Returns ``True``
        when fully drained; ``False`` when the timeout expired with
        flushes still active (never silently — callers that would
        otherwise treat a timed-out drain as quiescence must check)."""
        with self._inflight_cv:
            return bool(self._inflight_cv.wait_for(
                lambda: self._active == 0, timeout=timeout))

    def __enter__(self) -> "BatchScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def close(self) -> None:
        """Permanently shut down: refuse new submissions, flush and
        resolve everything already queued, join in-flight flushes and
        stop the worker threads.

        ``_closed`` is set under ``_lock`` *before* the final flush so
        a concurrent :meth:`submit` either raises or its request is
        caught by that flush — it can never enqueue after the final
        flush with no timer thread left to serve it.
        """
        with self._lock:
            self._closed = True
        self.stop()
        self._stop_completer()

    def _timer_loop(self) -> None:
        tick = max(self.max_wait_s / 4.0, 1e-4)
        while not self._stop.wait(tick):
            try:
                self._flush_expired()
            except Exception as e:
                # The flush's futures already carry the exception; the
                # timer must survive so later buckets still get
                # flushed.  But never silently: count it (surfaced in
                # snapshot()/format_report()) and warn once.
                self.metrics.record_error(
                    "timer_flush",
                    warn=f"serve_lp: background flush failed ({e!r}); "
                         "the failing flush's futures carry the "
                         "exception and the timer thread is still "
                         "running (counted in ServeMetrics errors)")

    # -- the pipelined solve path ----------------------------------------

    def _solve(self, bm: int, reqs: List[_Pending], *, reason: str,
               pre_counted: bool = False) -> None:
        """Flush one bucket (the single-bucket unit)."""
        self._solve_unit([(bm, reqs)], reason=reason,
                         pre_counted=pre_counted)

    def _solve_unit(self, parts: List[Tuple[int, List[_Pending]]], *,
                    reason: str, pre_counted: bool = False) -> None:
        """Flush one unit — one bucket, or several fused: assemble,
        dispatch and — pipelined — hand completion to the worker.  A
        fused unit solves every member's requests in a single
        super-batch padded to the largest member's ``m_pad`` (the
        per-problem results are bit-identical either way — padding
        columns are neutral).  Errors on the assemble/dispatch path
        reach every future of this unit and re-raise.

        Requests whose future was cancelled while queued (deadline
        expiry in the RPC layer) are dropped here — expired work is
        cancelled instead of solved; a unit that cancels down to
        nothing is skipped entirely.  Surviving futures are *claimed*
        (``set_running_or_notify_cancel``) so a later ``cancel()`` from
        another thread returns False instead of racing the completion
        scatter."""
        tracer = self.tracer
        live: List[Tuple[int, List[_Pending]]] = []
        for bm_i, q in parts:
            kept: List[_Pending] = []
            for r in q:
                if r.future.set_running_or_notify_cancel():
                    kept.append(r)
                else:
                    tracer.end(r.qspan, cancelled=True)
                    tracer.end(r.span, cancelled=True)
                    r.qspan = r.span = None
            if kept:
                live.append((bm_i, kept))
        if not live:
            if pre_counted:
                with self._inflight_cv:
                    self._active -= 1
                    self._inflight_cv.notify_all()
            return
        bm = max(bm_i for bm_i, _ in live)
        reqs = [r for _, q in live for r in q]
        if not pre_counted:
            with self._inflight_cv:
                self._active += 1
        try:
            unit = self._assemble(bm, reqs, reason,
                                  n_buckets=len(live))
            self._dispatch(unit)
        except Exception as e:  # propagate to every waiter, don't hang
            with self._inflight_cv:
                self._active -= 1
                self._inflight_cv.notify_all()
            for r in reqs:
                _try_set_exception(r.future, e)
                tracer.end(r.qspan, error=type(e).__name__)
                tracer.end(r.span, error=type(e).__name__)
                r.qspan = r.span = None
            raise
        if not self.pipeline:
            err = self._complete_unit(unit)
            if err is not None:
                raise err

    def _assemble(self, bm: int, reqs: List[_Pending],
                  reason: str, n_buckets: int = 1) -> _InflightFlush:
        """Host-side stage: lease packed buffers, fill them directly in
        the SoA layout (neutral columns/problems are a_x = a_y = 0,
        b = PAD_B, c = (1, 0), m_valid = 0 — no AoS intermediate, no
        device-side re-stack) and resolve the executable."""
        B = len(reqs)
        pinned = self._pin_for_bucket(bm, B)
        b_pad = bucket_batch(B, self._unit_for_tile(pinned.tile))
        spec = ExecSpec(bucket_m=bm, b_pad=b_pad, solver=pinned,
                        n_devices=len(self._devices),
                        sharding=self.sharding)
        # The flush is named before any work so its queue.wait /
        # flush.* spans can carry the name from the start.
        with self._lock:
            self._flush_seq += 1
            seq = self._flush_seq
        name = f"flush-{seq} m{bm}xb{b_pad}"
        t0 = time.perf_counter()
        tracer = self.tracer
        trace_id = None
        asm_span = None
        if tracer.enabled:
            primary = next(
                (r for r in reqs if r.trace is not None), None)
            if primary is not None:
                trace_id = primary.trace.trace_id
                asm_span = tracer.start_span(
                    "flush.assemble", trace_id,
                    parent_id=(primary.span.span_id
                               if primary.span is not None else None),
                    t_start=t0, flush=name, bucket_m=bm, b_pad=b_pad,
                    n_real=B, n_buckets=n_buckets, reason=reason,
                    trace_ids=tuple(r.trace.trace_id for r in reqs
                                    if r.trace is not None))
            for r in reqs:
                tracer.end(r.qspan, t_end=t0, flush=name)
                r.qspan = None
        self.metrics.record_queue_waits(
            [(t0 - r.t_submit,
              r.trace.trace_id if r.trace is not None else None)
             for r in reqs])
        key, bufs = self.buffers.lease(b_pad, bm, self._dtype)
        try:
            L, c, mv = bufs
            for i, r in enumerate(reqs):
                L[i, 0, :r.m] = r.ax
                L[i, 1, :r.m] = r.ay
                L[i, 2, :r.m] = r.b
                c[i] = r.c
                mv[i, 0] = r.m
            exe = as_executable(self.cache.get(spec))
        except Exception:
            self.buffers.release(key, bufs)
            raise
        tracer.end(asm_span)
        return _InflightFlush(
            name=name, bucket_m=bm, b_pad=b_pad,
            reqs=reqs, reason=reason, exe=exe, buf_key=key, bufs=bufs,
            t_assemble=t0, n_buckets=n_buckets,
            trace_id=trace_id, asm_span=asm_span)

    def _dispatch(self, unit: _InflightFlush) -> None:
        """Async stage: reserve an in-flight slot (backpressure — blocks
        while ``max_inflight`` flushes are in flight), enqueue the solve
        on the device and hand the unit to the completion worker."""
        tracer = self.tracer
        dspan = None
        if tracer.enabled and unit.trace_id is not None:
            # Covers backpressure wait + the async dispatch call; the
            # device.solve span then starts where this one ends.
            dspan = tracer.start_span(
                "flush.dispatch", unit.trace_id,
                parent_id=(unit.asm_span.span_id
                           if unit.asm_span is not None else None),
                flush=unit.name, bucket_m=unit.bucket_m)
        if self.pipeline:
            with self._inflight_cv:
                self._inflight_cv.wait_for(
                    lambda: self._inflight < self.max_inflight)
                self._inflight += 1
                unit.counted = True
        L, c, mv = unit.bufs
        try:
            if tracer.annotate_device:
                with _device_annotation(unit.name):
                    unit.handle = unit.exe.dispatch(L, c, mv)
            else:
                unit.handle = unit.exe.dispatch(L, c, mv)
        except Exception:
            self._release_slot(unit)
            self.buffers.release(unit.buf_key, unit.bufs)
            raise
        unit.t_dispatch = time.perf_counter()
        tracer.end(dspan, t_end=unit.t_dispatch,
                   launches=getattr(unit.exe, "n_launches", 1))
        self.metrics.record_dispatch()
        if self.pipeline:
            self._ensure_completer()
            self._work_q.put(unit)

    def _release_slot(self, unit: _InflightFlush) -> None:
        if unit.counted:
            with self._inflight_cv:
                self._inflight -= 1
                self._inflight_cv.notify_all()
            unit.counted = False

    def _ensure_completer(self) -> None:
        t = self._completer
        if t is not None and t.is_alive():
            return
        with self._lock:
            if self._completer is None or not self._completer.is_alive():
                self._completer = threading.Thread(
                    target=self._completion_loop,
                    name="serve-lp-complete", daemon=True)
                self._completer.start()

    def _stop_completer(self) -> None:
        t = self._completer
        if t is not None and t.is_alive():
            self._work_q.put(None)
            t.join()
        self._completer = None

    def _completion_loop(self) -> None:
        """The completion worker: finish dispatched flushes in dispatch
        order, off the submit/assembly path."""
        while True:
            unit = self._work_q.get()
            if unit is None:
                return
            try:
                self._complete_unit(unit)
            except Exception as e:   # must never die mid-queue
                self.metrics.record_error(
                    "completion_worker",
                    warn=f"serve_lp: completion worker error {e!r}")

    def _complete_unit(self, unit: _InflightFlush
                       ) -> Optional[BaseException]:
        """Join stage: block on the device results, return the leased
        buffers (safe only now — see :class:`_FlushBufferPool`), record
        metrics, scatter futures.  Returns the solve error, if any,
        instead of raising (the sync path re-raises it; the worker
        routes it to futures + error counters)."""
        err: Optional[BaseException] = None
        x = feas = None
        try:
            x, feas = unit.exe.complete(unit.handle)
        except Exception as e:
            err = e
        unit.t_complete = time.perf_counter()
        unit.handle = None
        # Device is synchronized (or dead): the host buffers are free.
        self.buffers.release(unit.buf_key, unit.bufs)
        self._release_slot(unit)
        with self._inflight_cv:
            self._active -= 1
            self._inflight_cv.notify_all()
        self.metrics.record_complete()
        tracer = self.tracer
        traced = tracer.enabled and unit.trace_id is not None
        parent = (unit.asm_span.span_id
                  if unit.asm_span is not None else None)
        sspan = None
        if traced:
            # One device.solve span per launch group, reconstructed
            # from the host-observed dispatch -> complete window (the
            # device service interval the union/idle math runs on).
            self._record_device_spans(unit, parent)
            sspan = tracer.start_span(
                "flush.scatter", unit.trace_id, parent_id=parent,
                t_start=unit.t_complete, flush=unit.name,
                bucket_m=unit.bucket_m)
        if err is not None:
            # Order matters: commit the errored spans, fire the flight
            # recorder (via the record_error hook) so its snapshot holds
            # them as evidence, and only then settle the futures — a
            # caller woken by its future sees evidence fully captured.
            for r in unit.reqs:
                tracer.end(r.span, error=type(err).__name__,
                           flush=unit.name)
                r.span = None
            tracer.end(sspan, error=type(err).__name__)
            if self.pipeline:
                self.metrics.record_error(
                    "solve",
                    warn=f"serve_lp: {unit.name} failed ({err!r}); its "
                         "futures carry the exception")
            for r in unit.reqs:
                _try_set_exception(r.future, err)
            unit.done.set()
            return err
        B = len(unit.reqs)
        now = time.perf_counter()
        # Metrics before the scatter: a caller woken by future.result()
        # observes a fully consistent snapshot (flush counted, buffers
        # back in the pool, in-flight gauge decremented).  The flush's
        # futures were claimed in _solve, so a concurrent cancel can no
        # longer settle them — and the scatter below tolerates a lost
        # settle race anyway rather than orphaning the rest of the
        # flush.
        for r in unit.reqs:
            if not r.future.done():
                self.metrics.record_latency(
                    now - r.t_submit,
                    trace_id=(r.trace.trace_id
                              if r.trace is not None else None))
        self.metrics.record_flush(
            n_real=B, b_pad=unit.b_pad, bucket_m=unit.bucket_m,
            sum_m=sum(r.m for r in unit.reqs),
            solve_seconds=unit.t_complete - unit.t_dispatch,
            assemble_seconds=unit.t_dispatch - unit.t_assemble,
            reason=unit.reason,
            n_buckets=unit.n_buckets,
            launches=getattr(unit.exe, "n_launches", 1),
            shards=getattr(unit.exe, "shards", ()),
            trace_id=unit.trace_id)
        if self.recorder is not None:
            self.recorder.maybe_check_p99(
                lambda: self.metrics.percentile(99.0))
        for i, r in enumerate(unit.reqs):
            if r.future.done():
                tracer.end(r.span, t_end=now, flush=unit.name,
                           dropped=True)
                r.span = None
                continue
            xi = np.asarray(x[i])
            _try_set_result(r.future, LPResult(
                x=xi,
                feasible=bool(feas[i]),
                objective=float(r.c @ xi),
                m=r.m,
                bucket_m=unit.bucket_m,
                batch_size=B,
                latency_s=now - r.t_submit,
            ))
            tracer.end(r.span, t_end=now, flush=unit.name,
                       feasible=bool(feas[i]))
            r.span = None
        tracer.end(sspan)
        unit.done.set()
        return None

    def _record_device_spans(self, unit: _InflightFlush,
                             parent: Optional[str]) -> None:
        """Emit per-launch-group ``device.solve`` spans for one
        completed flush: mesh executables get one span per
        :class:`~repro.serve_lp.mesh_layout.LaunchGroup` (its device
        indices and row geometry as attrs); pmap/jit fallbacks get a
        single span over every participating device."""
        layout = getattr(unit.exe, "layout", None)
        groups = getattr(layout, "groups", ()) if layout is not None \
            else ()
        if groups:
            for g in groups:
                self.tracer.record(
                    "device.solve", unit.trace_id, parent,
                    unit.t_dispatch, unit.t_complete,
                    flush=unit.name, bucket_m=unit.bucket_m,
                    devices=g.device_indices,
                    rows_per_device=g.rows_per_device, rows=g.rows)
            return
        shards = tuple(getattr(unit.exe, "shards", ()) or ())
        devices = (tuple(i for i, s in enumerate(shards) if s)
                   or tuple(range(len(self._devices))))
        self.tracer.record(
            "device.solve", unit.trace_id, parent,
            unit.t_dispatch, unit.t_complete,
            flush=unit.name, bucket_m=unit.bucket_m,
            devices=devices,
            rows=int(sum(shards)) if shards else unit.b_pad)
