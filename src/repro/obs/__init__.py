"""repro.obs — dependency-free observability for the serving stack.

End-to-end tracing, flush timelines, and a flight recorder: the
instrument that turns "device utilization near 100%" from a claim into
a measurement.  Everything here is stdlib-only (the no-new-deps rule)
and built so the *disabled* path costs nothing but a counter bump —
serving with tracing off must stay within noise of not having this
package at all.

Layers::

    trace     TraceContext (128-bit trace id), typed Spans, the
              SpanBuffer ring and the Tracer front door
    export    Chrome trace_event JSON (Perfetto-loadable), the span
              chain checker, and the measured device-idle fraction
    recorder  FlightRecorder: ring + scheduler-state snapshots dumped
              to a bounded JSON spool on errors / SLO violations /
              p99-threshold flushes
    log       stdlib-logging JSON formatter with trace_id/span_id/
              tenant/bucket injected from the active context
    profiler  opt-in jax.profiler TraceAnnotation / start_trace hooks
              so device traces line up with host spans

The span taxonomy (see README "Observability" for the full table):
``rpc.handle`` -> ``admit`` -> ``request`` -> ``queue.wait`` ->
``flush.assemble`` -> ``flush.dispatch`` -> ``device.solve`` (one per
launch group) -> ``flush.scatter``.
"""
from repro.obs.export import (check_span_chains, device_idle,
                              to_chrome_trace)
from repro.obs.log import JsonFormatter, setup_logging
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import (NOOP_TRACER, TRACE_HEADER, Span, SpanBuffer,
                             TraceContext, Tracer, current_context,
                             new_trace_context, parse_trace_header,
                             use_context)

__all__ = [
    "FlightRecorder", "JsonFormatter", "NOOP_TRACER", "Span",
    "SpanBuffer", "TRACE_HEADER", "TraceContext", "Tracer",
    "check_span_chains", "current_context", "device_idle",
    "new_trace_context", "parse_trace_header", "setup_logging",
    "to_chrome_trace", "use_context",
]
