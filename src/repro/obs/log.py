"""Structured JSON logging with trace context injection.

One stdlib-``logging`` formatter that renders each record as a single
JSON object and stamps it with whatever observability fields are bound
in the ambient context (:func:`repro.obs.trace.use_context`) —
trace_id, span_id, tenant, bucket — so a grep for a trace id surfaces
the log lines *and* the spans of the same request.

``setup_logging("json")`` is what ``rpc/__main__.py --log-format json``
and the serve entrypoint call; ``"text"`` keeps the classic one-line
format for interactive use.
"""
from __future__ import annotations

import json
import logging
import sys
import time
from typing import Any, Dict, Optional

from repro.obs.trace import current_context

# Fields every LogRecord carries that we either map explicitly or do
# not want echoed into the "extra" overflow.
_RESERVED = frozenset((
    "name", "msg", "args", "levelname", "levelno", "pathname", "filename",
    "module", "exc_info", "exc_text", "stack_info", "lineno", "funcName",
    "created", "msecs", "relativeCreated", "thread", "threadName",
    "processName", "process", "taskName", "message",
))

# Context keys promoted to top-level JSON fields (anything else bound
# via use_context lands under "ctx").
_CONTEXT_FIELDS = ("trace_id", "span_id", "tenant", "bucket")


class JsonFormatter(logging.Formatter):
    """Render records as one JSON object per line.

    Layout: ``ts`` (unix seconds), ``level``, ``logger``, ``msg``,
    then the promoted context fields when bound, ``exc`` for
    exceptions, and any ``extra=`` keys verbatim.  Values that json
    can't serialize fall back to ``repr`` — a log call must never
    throw out of the formatter.
    """

    def format(self, record: logging.LogRecord) -> str:
        out: Dict[str, Any] = {
            "ts": round(record.created, 6),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        ctx = current_context()
        for key in _CONTEXT_FIELDS:
            if key in ctx:
                out[key] = ctx[key]
        rest = {k: v for k, v in ctx.items()
                if k not in _CONTEXT_FIELDS}
        if rest:
            out["ctx"] = rest
        for key, val in record.__dict__.items():
            if key not in _RESERVED and not key.startswith("_") \
                    and key not in out:
                out[key] = val
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        try:
            return json.dumps(out, default=repr)
        except (TypeError, ValueError):
            return json.dumps({"ts": out["ts"], "level": out["level"],
                               "logger": out["logger"],
                               "msg": str(out.get("msg"))})


class TextFormatter(logging.Formatter):
    """The classic human format, with trace id appended when bound."""

    def __init__(self) -> None:
        super().__init__(
            "%(asctime)s %(levelname)s %(name)s: %(message)s")
        self.converter = time.gmtime

    def format(self, record: logging.LogRecord) -> str:
        line = super().format(record)
        trace_id = current_context().get("trace_id")
        if trace_id:
            line += f" trace={trace_id}"
        return line


def setup_logging(fmt: str = "text", level: int = logging.INFO,
                  stream: Optional[Any] = None,
                  logger: Optional[logging.Logger] = None
                  ) -> logging.Handler:
    """Install one stream handler with the chosen formatter on the
    root (or given) logger, replacing handlers installed by a previous
    call.  Returns the handler (tests capture its stream)."""
    if fmt not in ("text", "json"):
        raise ValueError(f"log format {fmt!r} not in ('text', 'json')")
    target = logger if logger is not None else logging.getLogger()
    handler = logging.StreamHandler(stream or sys.stderr)
    handler.setFormatter(JsonFormatter() if fmt == "json"
                         else TextFormatter())
    handler.set_name("repro-obs")
    for h in list(target.handlers):
        if h.get_name() == "repro-obs":
            target.removeHandler(h)
    target.addHandler(handler)
    target.setLevel(level)
    return handler
