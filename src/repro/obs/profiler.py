"""Opt-in jax.profiler hooks that line device traces up with host spans.

Two thin wrappers, both import-gated so the obs package never drags
jax in (and keeps working when jax is absent):

* :func:`annotation` — a context manager emitting a
  ``jax.profiler.TraceAnnotation`` named like the host span, so the
  per-launch dispatch shows up as a labelled region in a device
  profile.  Falls back to a null context when jax (or the profiler)
  is unavailable.
* :class:`ProfileSession` — ``jax.profiler.start_trace`` /
  ``stop_trace`` bracketing for a whole bench run
  (``--jax-profile-dir``), tolerant of double-stops and missing jax.

Caveat (documented in the README): on CPU backends the device
"profile" is host threads running compiled XLA code — annotations
still nest correctly, but there is no hardware timeline to align
against; treat CPU profiles as structural, not quantitative.
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator, Optional

try:  # pragma: no cover - exercised only when jax present (it is in CI)
    import jax.profiler as _jax_profiler
except Exception:  # pragma: no cover
    _jax_profiler = None


def available() -> bool:
    return _jax_profiler is not None


@contextlib.contextmanager
def annotation(name: str) -> Iterator[None]:
    """``jax.profiler.TraceAnnotation(name)`` when jax is importable,
    else a no-op block."""
    if _jax_profiler is None:
        yield
        return
    try:
        cm: Any = _jax_profiler.TraceAnnotation(name)
    except Exception:
        yield
        return
    with cm:
        yield


class ProfileSession:
    """Start/stop a jax profiler trace around a run.

    ``ProfileSession(log_dir).start()`` is a no-op (returning False)
    when jax or its profiler is unavailable; ``stop()`` tolerates
    never-started and double-stop so shutdown paths can call it
    unconditionally.
    """

    def __init__(self, log_dir: Optional[str]):
        self.log_dir = log_dir
        self.active = False

    def start(self) -> bool:
        if not self.log_dir or _jax_profiler is None or self.active:
            return False
        try:
            _jax_profiler.start_trace(self.log_dir)
        except Exception:
            return False
        self.active = True
        return True

    def stop(self) -> bool:
        if not self.active:
            return False
        self.active = False
        try:
            _jax_profiler.stop_trace()
        except Exception:
            return False
        return True

    def __enter__(self) -> "ProfileSession":
        self.start()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()
