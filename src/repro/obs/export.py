"""Span exporters: Chrome ``trace_event`` JSON, span-chain validation,
and the measured device-idle fraction.

The Chrome format (loadable in Perfetto / ``chrome://tracing``) is a
flat ``{"traceEvents": [...]}`` list.  We render:

* one **complete** ("X") event per ``device.solve`` span per device it
  covered, on a named per-device track — the flush timeline the paper's
  utilization claim needs;
* one "X" event per flush-plane span (``flush.assemble`` /
  ``flush.dispatch`` / ``flush.scatter``) on a named per-m-bucket
  track, so each bucket's cadence reads as a lane;
* request-plane spans (``rpc.handle``, ``admit``, ``request``,
  ``queue.wait``) as **async nestable** ("b"/"e") events grouped by
  trace id — thousands of concurrent requests render as their own
  little chains instead of a single malformed stack.

Timestamps are microseconds relative to the earliest span in the
export (Chrome wants small positive ``ts``).

:func:`device_idle` turns the per-device ``device.solve`` tracks into
the *measured* idle fraction: union the busy intervals per device,
divide by the observation window.  This replaces the serving metrics'
"device-idle-gap estimate" whenever tracing is on.

:func:`check_span_chains` is the ``--assert-trace`` contract: every
completed request trace must have a full chain
``request -> queue.wait -> (its flush's) assemble -> dispatch ->
device.solve -> scatter`` with sane parent links and ordering.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import Span

# Track-id blocks for the synthetic Chrome thread ids.
_PID = 1
_TID_DEVICE = 1000     # + device index
_TID_BUCKET = 2000     # + dense bucket index (sorted m)

REQUEST_PLANE = ("rpc.handle", "admit", "request", "queue.wait")
FLUSH_PLANE = ("flush.assemble", "flush.dispatch", "flush.scatter")


def _us(t: float, t0: float) -> float:
    return round((t - t0) * 1e6, 3)


def to_chrome_trace(spans: Sequence[Span]) -> Dict[str, Any]:
    """Render a span snapshot as a Chrome ``trace_event`` object."""
    spans = [s for s in spans if s.t_end >= s.t_start]
    if not spans:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(s.t_start for s in spans)
    events: List[Dict[str, Any]] = []

    def meta(tid: int, name: str, sort: int) -> None:
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name", "args": {"name": name}})
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_sort_index",
                       "args": {"sort_index": sort}})

    events.append({"ph": "M", "pid": _PID, "name": "process_name",
                   "args": {"name": "repro.serve_lp"}})

    devices = sorted({int(d) for s in spans if s.name == "device.solve"
                      for d in s.attrs.get("devices", ())})
    for d in devices:
        meta(_TID_DEVICE + d, f"device{d} solve", 10 + d)
    buckets = sorted({int(s.attrs["bucket_m"]) for s in spans
                      if s.name in FLUSH_PLANE and "bucket_m" in s.attrs})
    bucket_tid = {bm: _TID_BUCKET + i for i, bm in enumerate(buckets)}
    for bm, tid in bucket_tid.items():
        meta(tid, f"bucket m={bm}", 100 + tid - _TID_BUCKET)

    for s in spans:
        args = {"trace_id": s.trace_id, "span_id": s.span_id,
                **{k: v for k, v in s.attrs.items()
                   if k != "trace_ids"}}
        if "trace_ids" in s.attrs:
            args["n_traces"] = len(s.attrs["trace_ids"])
        if s.name == "device.solve":
            for d in s.attrs.get("devices", ()):
                events.append({
                    "name": s.name, "ph": "X", "pid": _PID,
                    "tid": _TID_DEVICE + int(d),
                    "ts": _us(s.t_start, t0),
                    "dur": max(_us(s.t_end, t0) - _us(s.t_start, t0),
                               0.001),
                    "cat": "device", "args": args})
        elif s.name in FLUSH_PLANE and "bucket_m" in s.attrs:
            events.append({
                "name": s.name, "ph": "X", "pid": _PID,
                "tid": bucket_tid[int(s.attrs["bucket_m"])],
                "ts": _us(s.t_start, t0),
                "dur": max(_us(s.t_end, t0) - _us(s.t_start, t0),
                           0.001),
                "cat": "flush", "args": args})
        else:
            # Request plane: async nestable pairs keyed by trace id.
            common = {"name": s.name, "cat": "request", "pid": _PID,
                      "tid": 1, "id": s.trace_id}
            events.append({**common, "ph": "b",
                           "ts": _us(s.t_start, t0), "args": args})
            events.append({**common, "ph": "e",
                           "ts": _us(s.t_end, t0)})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: Sequence[Span], path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(to_chrome_trace(spans), f)


def validate_chrome_trace(obj: Dict[str, Any]) -> None:
    """Structural check of an exported trace object (tests/CI): raises
    ValueError on anything Perfetto would choke on."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace object needs a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    open_async: Dict[Tuple[str, str], int] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object")
        ph = e.get("ph")
        if ph not in ("X", "M", "b", "e"):
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        if "pid" not in e:
            raise ValueError(f"event {i}: missing pid")
        if ph == "M":
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"event {i}: missing name")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        elif ph == "b":
            open_async[(e.get("id"), e["name"])] = \
                open_async.get((e.get("id"), e["name"]), 0) + 1
        elif ph == "e":
            key = (e.get("id"), e["name"])
            if open_async.get(key, 0) < 1:
                raise ValueError(
                    f"event {i}: async end without begin for {key}")
            open_async[key] -= 1
    dangling = {k: v for k, v in open_async.items() if v}
    if dangling:
        raise ValueError(f"unbalanced async events: {dangling}")


# -- measured device idleness ----------------------------------------------

def _merge(intervals: List[Tuple[float, float]]
           ) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    for lo, hi in sorted(intervals):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def device_idle(spans: Sequence[Span],
                window: Optional[Tuple[float, float]] = None
                ) -> Dict[str, Any]:
    """The measured device-idle picture from ``device.solve`` spans.

    Per device: busy = union of its solve intervals; idle fraction =
    1 - busy / window.  ``window`` defaults to the [earliest start,
    latest end] over all device spans — the span of time the device
    plane was observably in use.  Returns zeros when no device spans
    exist (nothing was traced)."""
    per_dev: Dict[int, List[Tuple[float, float]]] = {}
    for s in spans:
        if s.name != "device.solve" or s.t_end <= s.t_start:
            continue
        for d in s.attrs.get("devices", ()):
            per_dev.setdefault(int(d), []).append((s.t_start, s.t_end))
    if not per_dev:
        return {"devices": {}, "window_s": 0.0,
                "idle_frac": 0.0, "busy_s": 0.0, "idle_s": 0.0}
    if window is None:
        lo = min(iv[0] for ivs in per_dev.values() for iv in ivs)
        hi = max(iv[1] for ivs in per_dev.values() for iv in ivs)
    else:
        lo, hi = window
    span_s = max(hi - lo, 1e-12)
    devices: Dict[str, Dict[str, float]] = {}
    busy_total = 0.0
    for d, ivs in sorted(per_dev.items()):
        busy = sum(min(b, hi) - max(a, lo)
                   for a, b in _merge(ivs) if min(b, hi) > max(a, lo))
        busy_total += busy
        devices[str(d)] = {
            "busy_s": busy,
            "idle_s": span_s - busy,
            "idle_frac": max(0.0, 1.0 - busy / span_s),
            "n_solves": len(ivs),
        }
    n = len(per_dev)
    return {
        "devices": devices,
        "window_s": span_s,
        "busy_s": busy_total,
        "idle_s": n * span_s - busy_total,
        "idle_frac": max(0.0, 1.0 - busy_total / (n * span_s)),
    }


# -- the span-chain contract -----------------------------------------------

def check_span_chains(spans: Sequence[Span]) -> Dict[str, Any]:
    """Verify every completed request trace has a full span chain.

    A *completed* request is one whose ``request`` span ended without a
    ``cancelled``/``error`` attribute.  For each, require:

    * a ``queue.wait`` span in the same trace, parented to the request
      span, starting no earlier than it;
    * membership in exactly one flush (the ``trace_ids`` attr of a
      ``flush.assemble`` span);
    * that flush having ``flush.dispatch``, at least one
      ``device.solve``, and ``flush.scatter`` spans, ordered
      ``assemble.start <= dispatch.start <= solve.start <=
      solve.end <= scatter.end``.

    Returns ``{"complete": n, "problems": [...]}``; an empty problem
    list is the contract ``--assert-trace`` enforces.
    """
    spans = list(spans)
    by_trace: Dict[str, List[Span]] = {}
    for s in spans:
        by_trace.setdefault(s.trace_id, []).append(s)
    flushes: Dict[str, Dict[str, List[Span]]] = {}
    membership: Dict[str, List[str]] = {}
    for s in spans:
        fl = s.attrs.get("flush")
        if fl:
            flushes.setdefault(fl, {}).setdefault(s.name, []).append(s)
        if s.name == "flush.assemble":
            for tid in s.attrs.get("trace_ids", ()):
                membership.setdefault(tid, []).append(
                    s.attrs.get("flush", ""))
    problems: List[str] = []
    n_complete = 0
    for trace_id, ss in by_trace.items():
        reqs = [s for s in ss if s.name == "request"]
        if not reqs:
            continue    # flush-plane primary trace or rpc-only trace
        for req in reqs:
            if req.attrs.get("cancelled") or req.attrs.get("error"):
                continue
            n_complete += 1
            qs = [s for s in ss if s.name == "queue.wait"
                  and s.parent_id == req.span_id]
            if not qs:
                problems.append(
                    f"{trace_id}: no queue.wait child of request")
                continue
            q = qs[0]
            if q.t_start < req.t_start - 1e-6:
                problems.append(
                    f"{trace_id}: queue.wait starts before request")
            names = membership.get(trace_id, [])
            if not names:
                problems.append(
                    f"{trace_id}: no flush lists this trace")
                continue
            fl = names[0]
            unit = flushes.get(fl, {})
            missing = [n for n in ("flush.assemble", "flush.dispatch",
                                   "device.solve", "flush.scatter")
                       if not unit.get(n)]
            if missing:
                problems.append(
                    f"{trace_id}: flush {fl} missing {missing}")
                continue
            asm = unit["flush.assemble"][0]
            disp = unit["flush.dispatch"][0]
            sca = unit["flush.scatter"][0]
            for dev in unit["device.solve"]:
                ordered = (asm.t_start <= disp.t_start + 1e-6
                           <= dev.t_start + 2e-6
                           and dev.t_end <= sca.t_end + 1e-6)
                if not ordered:
                    problems.append(
                        f"{trace_id}: flush {fl} spans out of order")
                    break
            if q.t_end > asm.t_end + 1e-6:
                problems.append(
                    f"{trace_id}: queue.wait ends after assemble ends")
    return {"complete": n_complete, "problems": problems,
            "traces": len(by_trace), "flushes": len(flushes)}
