"""The flight recorder: evidence capture at the moment things go wrong.

A :class:`FlightRecorder` owns a pointer to the live
:class:`~repro.obs.trace.Tracer` ring and a ``state_fn`` returning the
scheduler's debug state (queue depths, in-flight flushes, buffer-pool
leases, per-device row counts).  :meth:`trigger` snapshots both plus
the trigger reason into one JSON file in a bounded spool directory —
the last N incidents survive, each self-contained and diffable.

Triggers wired by the serving stack:

* every ``ServeMetrics.record_error`` (the metrics error hook);
* an SLO violation on the RPC plane (deadline expiry answering 504);
* a flush completing while the live p99 exceeds the configured
  threshold (checked post-flush, debounced).

Debounce: incident storms (one bad executable failing every flush)
must not turn the spool into an I/O hot loop, so triggers within
``min_interval_s`` of the last written snapshot are counted and
dropped.  Everything here is best-effort — a failing disk write is
counted, never raised into the serve loop.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs.trace import Tracer


class FlightRecorder:
    """Snapshot the span ring + scheduler state to a bounded spool.

    Parameters
    ----------
    spool_dir:
        directory for snapshot files (created on first write).
    tracer:
        the live tracer whose ring is dumped; a disabled tracer is
        fine (snapshots then carry only state, no spans).
    state_fn:
        zero-arg callable returning a JSON-serializable scheduler
        state dict; bound later via :meth:`bind_state` when the
        recorder is constructed before the scheduler.
    max_snapshots:
        spool bound — oldest snapshot files beyond this are deleted.
    min_interval_s:
        debounce window between written snapshots.
    p99_threshold_s:
        when set, :meth:`check_p99` triggers on a live p99 above it.
    max_spans:
        cap on spans embedded per snapshot (newest kept).
    """

    def __init__(self, spool_dir: str, *,
                 tracer: Optional[Tracer] = None,
                 state_fn: Optional[Callable[[], Dict[str, Any]]] = None,
                 max_snapshots: int = 50,
                 min_interval_s: float = 1.0,
                 p99_threshold_s: Optional[float] = None,
                 max_spans: int = 4096):
        if max_snapshots < 1:
            raise ValueError(f"max_snapshots={max_snapshots} < 1")
        self.spool_dir = str(spool_dir)
        self.tracer = tracer
        self._state_fn = state_fn
        self.max_snapshots = int(max_snapshots)
        self.min_interval_s = float(min_interval_s)
        self.p99_threshold_s = p99_threshold_s
        self.max_spans = int(max_spans)
        self._lock = threading.Lock()
        self._seq = 0
        self._t_last_write: Optional[float] = None
        self._t_last_p99: Optional[float] = None
        self.triggers = 0           # trigger() calls
        self.written = 0            # snapshots actually written
        self.suppressed = 0         # debounced triggers
        self.write_errors = 0

    def bind_state(self, state_fn: Callable[[], Dict[str, Any]]) -> None:
        self._state_fn = state_fn

    # -- trigger entry points ---------------------------------------------

    def on_error(self, kind: str) -> Optional[str]:
        """The ``ServeMetrics`` error-hook adapter."""
        return self.trigger(f"error:{kind}")

    def check_p99(self, p99_s: float) -> Optional[str]:
        """Trigger when the live p99 exceeds the configured threshold
        (call with the current percentile; cheap no-op when no
        threshold is set)."""
        if self.p99_threshold_s is None or p99_s <= self.p99_threshold_s:
            return None
        return self.trigger(
            "p99_threshold",
            extra={"p99_s": p99_s, "threshold_s": self.p99_threshold_s})

    def maybe_check_p99(self,
                        p99_fn: Callable[[], float]) -> Optional[str]:
        """Interval-gated :meth:`check_p99` for hot paths: computing a
        live percentile sorts the reservoir, so the scheduler calls
        this per flush and the percentile is only computed at most once
        per ``min_interval_s`` (and never when no threshold is set)."""
        if self.p99_threshold_s is None:
            return None
        now = time.perf_counter()
        with self._lock:
            if (self._t_last_p99 is not None
                    and now - self._t_last_p99 < self.min_interval_s):
                return None
            self._t_last_p99 = now
        try:
            p99 = float(p99_fn())
        except Exception:
            return None
        return self.check_p99(p99)

    def trigger(self, reason: str,
                extra: Optional[Dict[str, Any]] = None) -> Optional[str]:
        """Capture one snapshot; returns the written path or ``None``
        (debounced / failed).  Never raises."""
        now = time.perf_counter()
        with self._lock:
            self.triggers += 1
            if (self._t_last_write is not None
                    and now - self._t_last_write < self.min_interval_s):
                self.suppressed += 1
                return None
            self._t_last_write = now
            self._seq += 1
            seq = self._seq
        try:
            return self._write(seq, reason, extra)
        except Exception:
            with self._lock:
                self.write_errors += 1
            return None

    # -- the snapshot body ------------------------------------------------

    def _write(self, seq: int, reason: str,
               extra: Optional[Dict[str, Any]]) -> str:
        state: Dict[str, Any] = {}
        if self._state_fn is not None:
            try:
                state = self._state_fn()
            except Exception as e:
                state = {"state_error": repr(e)}
        spans: List[Dict[str, Any]] = []
        ring: Dict[str, Any] = {}
        if self.tracer is not None:
            snap = self.tracer.spans()
            spans = [s.to_dict() for s in snap[-self.max_spans:]]
            ring = self.tracer.stats()
        body = {
            "schema": "repro.obs.flight/1",
            "seq": seq,
            "reason": reason,
            "unix_time": time.time(),
            "perf_counter": time.perf_counter(),
            "extra": extra or {},
            "scheduler": state,
            "ring": ring,
            "spans": spans,
        }
        os.makedirs(self.spool_dir, exist_ok=True)
        safe = "".join(ch if ch.isalnum() or ch in "._-" else "_"
                       for ch in reason)[:48]
        path = os.path.join(self.spool_dir,
                            f"flight-{seq:06d}-{safe}.json")
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(body, f)
        os.replace(tmp, path)
        with self._lock:
            self.written += 1
        self._prune()
        return path

    def _prune(self) -> None:
        try:
            names = sorted(n for n in os.listdir(self.spool_dir)
                           if n.startswith("flight-")
                           and n.endswith(".json"))
        except OSError:
            return
        for n in names[:-self.max_snapshots]:
            try:
                os.remove(os.path.join(self.spool_dir, n))
            except OSError:
                pass

    # -- views ------------------------------------------------------------

    def list_snapshots(self) -> List[str]:
        """Spool file names, oldest first."""
        try:
            return sorted(n for n in os.listdir(self.spool_dir)
                          if n.startswith("flight-")
                          and n.endswith(".json"))
        except OSError:
            return []

    def load_snapshot(self, name: str) -> Optional[Dict[str, Any]]:
        """Parse one spool file by name; ``None`` when missing or
        unparseable.  Names outside the spool are refused."""
        if os.path.basename(name) != name:
            return None
        try:
            with open(os.path.join(self.spool_dir, name),
                      encoding="utf-8") as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "spool_dir": self.spool_dir,
                "triggers": self.triggers,
                "written": self.written,
                "suppressed": self.suppressed,
                "write_errors": self.write_errors,
                "max_snapshots": self.max_snapshots,
                "min_interval_s": self.min_interval_s,
                "p99_threshold_s": self.p99_threshold_s,
            }
