"""Trace contexts, spans, the ring buffer, and the Tracer front door.

A :class:`TraceContext` is a 128-bit trace id plus the 64-bit id of the
span the next child should parent to.  It is accepted/emitted on the
RPC layer via the ``X-Trace-Id`` header and generated at
``BatchScheduler.submit`` for direct callers, then rides the pending
request through every hop of the serving stack.

Spans are *host-side* typed intervals on the monotonic
``time.perf_counter`` clock (one process, one clock — cross-span math
like the device-idle gap is exact, not NTP-fuzzy).  A span is recorded
only when it *ends*; the :class:`SpanBuffer` ring retains the last N
ended spans and counts what it dropped, so memory is bounded no matter
how long the server runs.

The overhead contract: a disabled :class:`Tracer` never allocates a
span — every ``start_span``/``record`` call returns ``None`` after one
plain counter bump (``noop_calls``), and ``spans_recorded`` stays 0.
The serve bench asserts exactly that on its no-trace path.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

# Wire header carrying the trace context: "<32 hex>" (trace id alone)
# or "<32 hex>-<16 hex>" (trace id + parent span id).
TRACE_HEADER = "X-Trace-Id"

_HEX = set("0123456789abcdef")


def _rand_hex(nbytes: int) -> str:
    return os.urandom(nbytes).hex()


def new_trace_context() -> "TraceContext":
    """A fresh root context: random 128-bit trace id, random 64-bit
    span id (the id request root spans parent to when the caller did
    not send one)."""
    return TraceContext(trace_id=_rand_hex(16), span_id=_rand_hex(8))


@dataclasses.dataclass(frozen=True)
class TraceContext:
    """Where in a trace we are: the trace id plus the current span id
    (children parent to ``span_id``)."""

    trace_id: str   # 32 lowercase hex chars (128-bit)
    span_id: str    # 16 lowercase hex chars (64-bit)

    def child_of(self, span_id: str) -> "TraceContext":
        """The context a child span should inherit: same trace,
        parented to ``span_id``."""
        return TraceContext(trace_id=self.trace_id, span_id=span_id)

    def header_value(self) -> str:
        return f"{self.trace_id}-{self.span_id}"


def parse_trace_header(value: Optional[str]) -> Optional[TraceContext]:
    """Parse an ``X-Trace-Id`` header into a context; ``None`` on a
    missing or malformed value (a bad trace header must never reject a
    request — tracing is best-effort metadata, not admission)."""
    if not value:
        return None
    parts = value.strip().lower().split("-")
    trace_id = parts[0]
    if len(trace_id) != 32 or not set(trace_id) <= _HEX:
        return None
    if len(parts) == 1:
        return TraceContext(trace_id=trace_id, span_id=_rand_hex(8))
    span_id = parts[1]
    if len(parts) != 2 or len(span_id) != 16 or not set(span_id) <= _HEX:
        return None
    return TraceContext(trace_id=trace_id, span_id=span_id)


@dataclasses.dataclass
class Span:
    """One typed host-side interval.  ``t_end`` is 0.0 until the span
    ends; only ended spans enter the ring."""

    trace_id: str
    span_id: str
    parent_id: Optional[str]
    name: str                      # taxonomy type, e.g. "queue.wait"
    t_start: float                 # perf_counter seconds
    t_end: float = 0.0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t_end - self.t_start)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "attrs": dict(self.attrs),
        }


class SpanBuffer:
    """Bounded ring of the last ``capacity`` ended spans.

    Appends are a slot write + index bump under a small lock (the
    "lock-free-ish" compromise: contention is one uncontended mutex in
    the common case, and correctness beats cleverness in the flight
    recorder's evidence store).  ``snapshot`` returns spans oldest to
    newest; ``dropped`` counts what the ring has already forgotten.
    """

    def __init__(self, capacity: int = 16384):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} < 1")
        self.capacity = int(capacity)
        self._slots: List[Optional[Span]] = [None] * self.capacity
        self._n = 0               # total spans ever appended
        self._lock = threading.Lock()

    def append(self, span: Span) -> None:
        with self._lock:
            self._slots[self._n % self.capacity] = span
            self._n += 1

    def __len__(self) -> int:
        with self._lock:
            return min(self._n, self.capacity)

    @property
    def total(self) -> int:
        with self._lock:
            return self._n

    @property
    def dropped(self) -> int:
        with self._lock:
            return max(0, self._n - self.capacity)

    def snapshot(self) -> List[Span]:
        """The ring's spans, oldest first."""
        with self._lock:
            n = self._n
            if n <= self.capacity:
                return [s for s in self._slots[:n] if s is not None]
            head = n % self.capacity
            return ([s for s in self._slots[head:] if s is not None]
                    + [s for s in self._slots[:head] if s is not None])

    def clear(self) -> None:
        with self._lock:
            self._slots = [None] * self.capacity
            self._n = 0


class Tracer:
    """The tracing front door every instrumented call site talks to.

    ``enabled`` is fixed at construction so hot paths may cache it as a
    plain bool.  Disabled tracers are pure no-ops: ``start_span`` /
    ``record`` return ``None`` after bumping ``noop_calls`` (a GIL-racy
    plain int — it is diagnostic, not an invariant), and nothing is
    allocated or locked.  ``spans_recorded`` counts ended spans that
    actually entered the ring; "tracing off => spans are no-ops" is
    asserted as ``spans_recorded == 0``.
    """

    def __init__(self, enabled: bool = True, capacity: int = 16384, *,
                 annotate_device: bool = False):
        self.enabled = bool(enabled)
        self.buffer = SpanBuffer(capacity)
        # Opt-in jax.profiler.TraceAnnotation around dispatches, so
        # device-profiler traces line up with host spans by name.
        self.annotate_device = bool(annotate_device)
        self.spans_started = 0
        self.spans_recorded = 0
        self.noop_calls = 0

    # -- span lifecycle ---------------------------------------------------

    def start_span(self, name: str, trace_id: str,
                   parent_id: Optional[str] = None,
                   t_start: Optional[float] = None,
                   **attrs: Any) -> Optional[Span]:
        """Open a span; returns ``None`` when disabled.  The span is
        not in the ring until :meth:`end`."""
        if not self.enabled:
            self.noop_calls += 1
            return None
        self.spans_started += 1
        return Span(trace_id=trace_id, span_id=_rand_hex(8),
                    parent_id=parent_id, name=name,
                    t_start=(time.perf_counter() if t_start is None
                             else t_start),
                    attrs=attrs)

    def end(self, span: Optional[Span],
            t_end: Optional[float] = None, **attrs: Any) -> None:
        """Close a span and commit it to the ring.  ``None`` (the
        disabled-tracer span) is accepted and ignored so call sites
        need no branching."""
        if span is None:
            self.noop_calls += 1
            return
        span.t_end = time.perf_counter() if t_end is None else t_end
        if attrs:
            span.attrs.update(attrs)
        self.buffer.append(span)
        self.spans_recorded += 1

    def record(self, name: str, trace_id: str,
               parent_id: Optional[str], t_start: float, t_end: float,
               **attrs: Any) -> Optional[Span]:
        """Record an already-measured interval (e.g. ``device.solve``
        reconstructed from dispatch/complete timestamps) in one call."""
        span = self.start_span(name, trace_id, parent_id,
                               t_start=t_start, **attrs)
        if span is not None:
            self.end(span, t_end=t_end)
        return span

    # -- views ------------------------------------------------------------

    def spans(self) -> List[Span]:
        return self.buffer.snapshot()

    def stats(self) -> Dict[str, int]:
        return {
            "enabled": int(self.enabled),
            "spans_started": self.spans_started,
            "spans_recorded": self.spans_recorded,
            "noop_calls": self.noop_calls,
            "ring_len": len(self.buffer),
            "ring_capacity": self.buffer.capacity,
            "ring_dropped": self.buffer.dropped,
        }


# The shared disabled tracer: what every instrumented component uses
# when no tracer was injected, so call sites never need None checks.
NOOP_TRACER = Tracer(enabled=False, capacity=1)


# -- ambient context (for log injection) -----------------------------------

_current: contextvars.ContextVar[Optional[Dict[str, Any]]] = \
    contextvars.ContextVar("repro_obs_context", default=None)


def current_context() -> Dict[str, Any]:
    """The ambient observability fields (trace_id, span_id, tenant,
    bucket, ...) bound by :func:`use_context`; empty when none."""
    ctx = _current.get()
    return dict(ctx) if ctx else {}


@contextlib.contextmanager
def use_context(**fields: Any) -> Iterator[None]:
    """Bind fields into the ambient context for the dynamic extent of
    the block — the JSON log formatter stamps them onto every record
    emitted inside.  Nested uses merge (inner wins)."""
    merged = current_context()
    merged.update({k: v for k, v in fields.items() if v is not None})
    token = _current.set(merged)
    try:
        yield
    finally:
        _current.reset(token)


def span_index(spans: List[Span]) -> Dict[str, Span]:
    """``span_id -> Span`` for a snapshot (helper for checkers)."""
    return {s.span_id: s for s in spans}


def spans_for_trace(spans: List[Span], trace_id: str) -> List[Span]:
    """All spans belonging to ``trace_id``: its own spans plus flush
    spans whose ``trace_ids`` membership attribute names it, plus the
    children of those flush spans (dispatch / device.solve / scatter
    carry only the flush's primary trace id — membership rides on the
    ``flush.assemble`` span to keep ring entries small)."""
    own = [s for s in spans if s.trace_id == trace_id]
    flushes: List[str] = []
    for s in spans:
        if (s.name == "flush.assemble"
                and trace_id in s.attrs.get("trace_ids", ())):
            flushes.append(s.attrs.get("flush", ""))
    if not flushes:
        return own
    flush_set = set(flushes)
    seen = {s.span_id for s in own}
    extra = [s for s in spans
             if s.attrs.get("flush") in flush_set
             and s.span_id not in seen]
    return own + extra


def flush_membership(spans: List[Span]
                     ) -> Dict[str, Tuple[str, ...]]:
    """``flush name -> member trace ids`` from assemble spans."""
    out: Dict[str, Tuple[str, ...]] = {}
    for s in spans:
        if s.name == "flush.assemble":
            out[s.attrs.get("flush", "")] = tuple(
                s.attrs.get("trace_ids", ()))
    return out
