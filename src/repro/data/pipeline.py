"""Deterministic, resumable, shardable token data pipeline.

Design constraints for 1000+-node training:
  * every host must be able to produce ITS shard of the global batch
    without coordination (pure function of (seed, step, host_shard)), so
    restarts and elastic re-sharding need no data redistribution;
  * the cursor is a single integer (step) — checkpointing the pipeline
    is free and exact;
  * two sources: a synthetic LM stream (self-contained; used by tests,
    smoke runs and benchmarks) and a binary token-file source (memory-
    mapped, strided across hosts).

The synthetic stream is not iid noise: it draws from a power-law unigram
distribution with Markov bigram structure so losses move like real text
(useful for convergence smoke tests).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | file
    path: Optional[str] = None  # token file (np.uint32 flat) for "file"
    # modality stubs
    n_prefix: int = 0          # vlm: patch embeddings per example
    d_model: int = 0
    enc_seq: int = 0           # encdec: frame embeddings per example


class TokenSource:
    """step -> global batch (deterministic)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "file":
            if not cfg.path or not Path(cfg.path).exists():
                raise FileNotFoundError(cfg.path)
            self._tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        else:
            self._tokens = None
            # power-law unigram + shift-register "bigram" mixing
            v = cfg.vocab
            ranks = np.arange(1, v + 1, dtype=np.float64)
            self._probs = (1.0 / ranks ** 1.1)
            self._probs /= self._probs.sum()

    def _synthetic(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xDA7A]))
        B, S = cfg.global_batch, cfg.seq_len
        base = rng.choice(cfg.vocab, size=(B, S + 1), p=self._probs)
        # Markov-ish structure: token_t depends on token_{t-1} half the time
        mix = rng.random((B, S + 1)) < 0.5
        shifted = np.roll(base, 1, axis=1)
        out = np.where(mix, (shifted * 31 + 7) % self.cfg.vocab, base)
        return out.astype(np.int32)

    def _from_file(self, step: int) -> np.ndarray:
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        n = B * (S + 1)
        total = len(self._tokens)
        start = (step * n) % max(total - n, 1)
        chunk = np.asarray(self._tokens[start:start + n], dtype=np.int32)
        return chunk.reshape(B, S + 1) % cfg.vocab

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = (self._from_file(step) if self.cfg.source == "file"
                else self._synthetic(step))
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        cfg = self.cfg
        if cfg.n_prefix and cfg.d_model:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 0x1113]))
            batch["patches"] = rng.standard_normal(
                (cfg.global_batch, cfg.n_prefix, cfg.d_model),
                dtype=np.float32)
        if cfg.enc_seq and cfg.d_model:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 0x2224]))
            batch["frames"] = rng.standard_normal(
                (cfg.global_batch, cfg.enc_seq, cfg.d_model),
                dtype=np.float32)
        return batch

    def host_batch(self, step: int, host_id: int, n_hosts: int
                   ) -> Dict[str, np.ndarray]:
        """The rows of the global batch owned by this host (contiguous
        stride — matches the ('pod','data') batch sharding)."""
        g = self.global_batch(step)
        B = self.cfg.global_batch
        assert B % n_hosts == 0
        per = B // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in g.items()}


def data_stream(cfg: DataConfig, start_step: int = 0,
                host_id: int = 0, n_hosts: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
    src = TokenSource(cfg)
    step = start_step
    while True:
        yield src.host_batch(step, host_id, n_hosts)
        step += 1


def for_model(cfg_model, seq_len: int, global_batch: int,
              seed: int = 0, source: str = "synthetic",
              path: Optional[str] = None) -> DataConfig:
    """DataConfig matching a ModelConfig's modality stubs."""
    return DataConfig(
        vocab=cfg_model.vocab,
        seq_len=(seq_len - cfg_model.n_prefix
                 if cfg_model.family == "vlm" else seq_len),
        global_batch=global_batch, seed=seed, source=source, path=path,
        n_prefix=cfg_model.n_prefix if cfg_model.family == "vlm" else 0,
        d_model=cfg_model.d_model if cfg_model.family in ("vlm", "encdec")
        else 0,
        enc_seq=cfg_model.enc_seq if cfg_model.family == "encdec" else 0,
    )
