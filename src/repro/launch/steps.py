"""Jitted, shard_map'd train / prefill / decode step builders.

The model forwards in models.transformer are per-rank code; these builders
wrap them in shard_map over the production mesh, attach sharding trees,
and compose the optimizer (with duplicated-KV grad sync, optional LP
trust-region clipping, and the optional manual-comm path with int8
error-feedback gradient compression across pods)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.launch.mesh import batch_axes, mesh_info
from repro.models.common import ModelConfig
from repro.models.transformer import build_model
from repro.optim import (AdamW, apply_updates, compressed_psum,
                         lp_constrain_updates, sync_duplicated_grads)


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_specs(cfg: ModelConfig, bax, kind: str) -> Dict[str, P]:
    b = P(bax) if bax else P(None)
    b2 = P(bax, None) if bax else P(None, None)
    b3 = P(bax, None, None) if bax else P(None, None, None)
    if kind == "decode":
        return {"token": b2, "pos": b}
    sp = {"tokens": b2}
    if kind == "train":
        sp["labels"] = b2
    if cfg.family == "vlm":
        sp["patches"] = b3
    if cfg.family == "encdec":
        sp["frames"] = b3
    return sp


@dataclasses.dataclass
class Program:
    """A compiled-able step: fn + sharding trees + abstract input builders."""
    mesh: Any
    cfg: ModelConfig
    model: Any
    step: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(self.step, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    mesh,
    optimizer: Optional[AdamW] = None,
    *,
    global_batch: int,
    lp_clip: bool = False,
    manual_comm: bool = False,
    compress_pod: bool = False,
    check_rep: bool = False,
) -> Program:
    mi = mesh_info(mesh)
    model = build_model(cfg, mi)
    optimizer = optimizer or AdamW()
    pspecs = model.full_param_specs()
    bax = batch_axes(mesh, global_batch)
    bspecs = _batch_specs(cfg, bax, "train")
    dup = model.kv_duplication()

    def per_rank_loss(params, batch):
        loss, metrics = model.loss(params, batch)
        n = metrics["tokens"].astype(jnp.float32)
        tot = loss * n
        for ax in mi.data_axes:
            tot = lax.psum(tot, ax)
            n = lax.psum(n, ax)
        return tot / n, {"ce": tot / n}

    loss_shmap = shard_map(
        per_rank_loss, mesh=mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(), {"ce": P()}), check_rep=check_rep)

    if manual_comm and cfg.fsdp:
        raise ValueError("manual_comm path requires fsdp=False "
                         "(FSDP grads already reduce-scatter in AD)")

    def step(params, opt_state, batch, extra):
        if manual_comm:
            err_in = extra.get("err")

            def per_rank(params, batch, err):
                def local_loss(p):
                    loss, metrics = model.loss(p, batch)
                    n = metrics["tokens"].astype(jnp.float32)
                    return loss * n, n

                (sl, n), g = jax.value_and_grad(
                    local_loss, has_aux=True)(params)

                # model-replicated leaves: per-rank grads are partial
                # (each TP rank only saw its shard's contribution)
                def _model_sync(x, sp):
                    names = set()
                    for e in tuple(sp):
                        if e is None:
                            continue
                        names.update(e if isinstance(e, tuple) else (e,))
                    if "model" in names or mi.model_size == 1:
                        return x
                    return lax.psum(x, "model")

                g = jax.tree.map(_model_sync, g, pspecs)
                inner = [ax for ax in mi.data_axes if ax != "pod"]
                for ax in inner:
                    g = jax.tree.map(lambda x: lax.psum(x, ax), g)
                    sl, n = lax.psum(sl, ax), lax.psum(n, ax)
                new_err = err
                if "pod" in mi.data_axes:
                    if compress_pod:
                        g, new_err = compressed_psum(g, err, "pod", 2)
                        g = jax.tree.map(lambda x: x * 2.0, g)  # sum, not mean
                    else:
                        g = jax.tree.map(lambda x: lax.psum(x, "pod"), g)
                    sl, n = lax.psum(sl, "pod"), lax.psum(n, "pod")
                g = jax.tree.map(lambda x: x / n, g)
                return sl / n, g, new_err

            loss, grads, new_err = shard_map(
                per_rank, mesh=mesh,
                in_specs=(pspecs, bspecs, pspecs),
                out_specs=(P(), pspecs, pspecs), check_rep=False)(
                    params, batch, err_in)
            extra = {"err": new_err}
        else:
            (loss, _), grads = jax.value_and_grad(
                lambda p: loss_shmap(p, batch), has_aux=True)(params)

        grads = sync_duplicated_grads(grads, dup, cfg.hd)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        s1 = jnp.ones((), jnp.float32)
        if lp_clip:
            updates, s1 = lp_constrain_updates(
                updates, grads, opt_state.m, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "lp_s1": s1}
        return params, opt_state, metrics, extra

    psh = _named(mesh, pspecs)
    from repro.optim.adamw import AdamWState
    opt_shardings = AdamWState(
        step=NamedSharding(mesh, P()), m=psh,
        v=jax.tree.map(lambda x: x, psh))
    extra_shardings = {"err": psh} if manual_comm else {}
    bsh = _named(mesh, bspecs)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "lp_s1": NamedSharding(mesh, P())}
    return Program(
        mesh=mesh, cfg=cfg, model=model, step=step,
        in_shardings=(psh, opt_shardings, bsh, extra_shardings),
        out_shardings=(psh, opt_shardings, metrics_sh, extra_shardings),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------

HBM_BYTES = 16e9  # v5e


def _serve_cfg(cfg: ModelConfig, mi, weight_resident):
    """Serving keeps weights resident (no per-token FSDP gather) whenever
    the TP shard fits HBM — a large collective-term win for decode
    (EXPERIMENTS.md section Perf).  weight_resident: None=auto."""
    if not cfg.fsdp:
        return cfg
    if weight_resident is None:
        shard = cfg.param_count() * 2 / max(mi.model_size, 1)
        weight_resident = shard < 0.75 * HBM_BYTES
    if weight_resident:
        import dataclasses as _dc
        return _dc.replace(cfg, fsdp=False)
    return cfg


def make_prefill_step(cfg: ModelConfig, mesh, *, global_batch: int,
                      check_rep: bool = False,
                      weight_resident: bool | None = None) -> Program:
    mi = mesh_info(mesh)
    cfg = _serve_cfg(cfg, mi, weight_resident)
    model = build_model(cfg, mi)
    pspecs = model.full_param_specs()
    bax = batch_axes(mesh, global_batch)
    bspecs = _batch_specs(cfg, bax, "prefill")
    cspecs = model.cache_specs(bax)
    logits_spec = P(bax, None) if bax else P(None, None)

    def per_rank(params, batch):
        return model.prefill(params, batch)

    step = shard_map(per_rank, mesh=mesh, in_specs=(pspecs, bspecs),
                     out_specs=(logits_spec, cspecs), check_rep=check_rep)
    return Program(
        mesh=mesh, cfg=cfg, model=model, step=step,
        in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       _named(mesh, cspecs)),
    )


def make_decode_step(cfg: ModelConfig, mesh, *, global_batch: int,
                     check_rep: bool = False,
                     weight_resident: bool | None = None) -> Program:
    mi = mesh_info(mesh)
    cfg = _serve_cfg(cfg, mi, weight_resident)
    model = build_model(cfg, mi)
    pspecs = model.full_param_specs()
    bax = batch_axes(mesh, global_batch)
    bspecs = _batch_specs(cfg, bax, "decode")
    cspecs = model.cache_specs(bax)
    logits_spec = P(bax, None) if bax else P(None, None)

    def per_rank(params, batch, cache):
        return model.decode(params, batch, cache)

    step = shard_map(per_rank, mesh=mesh,
                     in_specs=(pspecs, bspecs, cspecs),
                     out_specs=(logits_spec, cspecs), check_rep=check_rep)
    return Program(
        mesh=mesh, cfg=cfg, model=model, step=step,
        in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs),
                      _named(mesh, cspecs)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       _named(mesh, cspecs)),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# The paper's LP solver on the production mesh (batch-parallel)
# ---------------------------------------------------------------------------

def make_lp_step(mesh, *, batch: int, m: int, method: str = "rgb",
                 dtype=jnp.float32) -> Program:
    """Batch 2-D LP solve sharded over every mesh axis (pure data
    parallelism over problems — the paper's regime at cluster scale)."""
    from repro.core.lp import LPBatch
    from repro.core.seidel import solve_rgb, solve_naive

    mi = mesh_info(mesh)
    all_axes = mi.data_axes + (mi.model_axis,)
    bspec = {
        "A": P(all_axes, None, None), "b": P(all_axes, None),
        "c": P(all_axes, None), "m_valid": P(all_axes),
    }
    out_spec = {"x": P(all_axes, None), "feasible": P(all_axes),
                "objective": P(all_axes)}

    solver = solve_rgb if method == "rgb" else solve_naive

    def per_rank(batch_dict):
        sol = solver(LPBatch(**batch_dict))
        return {"x": sol.x, "feasible": sol.feasible,
                "objective": sol.objective}

    step = shard_map(per_rank, mesh=mesh, in_specs=(bspec,),
                     out_specs=out_spec, check_rep=False)
    return Program(mesh=mesh, cfg=None, model=None, step=step,
                   in_shardings=(_named(mesh, bspec),),
                   out_shardings=_named(mesh, out_spec))
