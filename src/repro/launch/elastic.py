"""Fault tolerance: heartbeat, straggler detection, supervised restart.

On a real multi-pod deployment each host runs the training driver under
this supervisor.  The failure model (matching TPU-pod operational
reality) is fail-stop per slice: a host that dies or stalls takes its
slice out, and recovery is restart-from-checkpoint of the job (possibly
on fewer/more slices — the checkpoint is mesh-independent, see
ckpt.checkpoint).  What this module provides:

  * ``Heartbeat`` — step + wall-time progress file, atomically updated.
  * ``StragglerMonitor`` — EWMA of step times; flags steps slower than
    ``threshold`` x the running median so the driver can log/alert (on a
    real pod: trigger preemptive re-slicing before a hard timeout).
  * ``Supervisor`` — runs the driver as a subprocess, watches the
    heartbeat, kills and relaunches from the latest checkpoint when the
    heartbeat stalls or the process dies.  Bounded restarts.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from collections import deque
from pathlib import Path
from typing import List, Optional


class Heartbeat:
    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"step": step, "t": time.time()}))
        os.rename(tmp, self.path)

    def read(self) -> Optional[dict]:
        try:
            return json.loads(self.path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def age(self) -> float:
        hb = self.read()
        return time.time() - hb["t"] if hb else float("inf")


class StragglerMonitor:
    """Flags abnormally slow steps (gray failure / straggling host)."""

    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.times: deque = deque(maxlen=window)
        self.flagged: List[int] = []

    def record(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = sorted(self.times)[len(self.times) // 2]
            slow = dt > self.threshold * med
            if slow:
                self.flagged.append(step)
        self.times.append(dt)
        return slow

    @property
    def median(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]


class Supervisor:
    """Restart-from-checkpoint supervision of a training driver."""

    def __init__(self, cmd: List[str], heartbeat_path: str | Path,
                 stall_timeout: float = 300.0, max_restarts: int = 10,
                 poll: float = 2.0):
        self.cmd = cmd
        self.hb = Heartbeat(heartbeat_path)
        self.stall_timeout = stall_timeout
        self.max_restarts = max_restarts
        self.poll = poll
        self.restarts = 0

    def run(self) -> int:
        while True:
            proc = subprocess.Popen(self.cmd, stdout=sys.stdout,
                                    stderr=sys.stderr)
            rc = self._watch(proc)
            if rc == 0:
                return 0
            self.restarts += 1
            if self.restarts > self.max_restarts:
                print(f"[supervisor] giving up after {self.restarts - 1} "
                      f"restarts", flush=True)
                return rc or 1
            print(f"[supervisor] relaunching (restart {self.restarts}); "
                  f"driver resumes from the latest checkpoint", flush=True)

    def _watch(self, proc: subprocess.Popen) -> int:
        start = time.time()
        while True:
            rc = proc.poll()
            if rc is not None:
                if rc != 0:
                    print(f"[supervisor] driver died rc={rc}", flush=True)
                return rc
            age = self.hb.age()
            if age == float("inf"):
                # grace period before the first beat (compile time etc.)
                age = time.time() - start
            if age > self.stall_timeout:
                print(f"[supervisor] heartbeat stalled "
                      f"({age:.0f}s) — killing driver", flush=True)
                proc.kill()
                proc.wait()
                return -9
            time.sleep(self.poll)
