"""Batched serving driver: continuous prefill + decode over a request
queue (the inference-side end-to-end example).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 16 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as steps_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh(len(jax.devices()), 1)
    B = args.batch
    s_max = args.prompt_len + args.gen

    prefill = steps_mod.make_prefill_step(cfg, mesh, global_batch=B).jit()
    decode = steps_mod.make_decode_step(cfg, mesh, global_batch=B).jit()

    model = steps_mod.make_prefill_step(cfg, mesh, global_batch=B).model
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    n_batches = -(-args.requests // B)
    done_tokens = 0
    t0 = time.time()
    for b in range(n_batches):
        prompts = rng.integers(0, cfg.vocab, (B, args.prompt_len),
                               dtype=np.int32)
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, cfg.n_prefix, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        logits, cache = prefill(params, batch)
        # right-pad the prefill cache out to s_max so decode can append
        cache = _pad_cache(model, cache, B, args.prompt_len, s_max)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        pos = jnp.full((B,), args.prompt_len, jnp.int32)
        for t in range(args.gen - 1):
            logits, cache = decode(params,
                                   {"token": tok, "pos": pos + t}, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        gen = np.concatenate(outs, axis=1)
        done_tokens += gen.size
        print(f"[serve] batch {b}: generated {gen.shape} tokens; "
              f"sample row: {gen[0][:8]}")
    dt = time.time() - t0
    print(f"[serve] {done_tokens} tokens in {dt:.2f}s "
          f"({done_tokens/dt:.1f} tok/s)")


def _pad_cache(model, cache, B, cur_len, s_max):
    """Grow every seq-length cache axis from cur_len to s_max."""
    def grow(x):
        # seq axes are the ones equal to cur_len in KV caches
        if x.ndim >= 3 and x.shape[2] == cur_len:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, s_max - cur_len)
            return jnp.pad(x, pad)
        return x
    return jax.tree.map(grow, cache)


if __name__ == "__main__":
    main()
