"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

For scaling beyond the 2-pod mesh (DESIGN.md section 5): when TP x DP
saturates ICI, layers are partitioned into S stages (the stacked layer
axis sharded over ``pipe``) and microbatches stream through with
boundary activations moved by ``lax.ppermute``.  The schedule is the
classic GPipe fill-drain: M microbatches finish in M + S - 1 ticks with
bubble fraction (S-1)/(M+S-1).

The engine is model-agnostic: any per-rank stage function
``fn(stage_params, x) -> x`` (e.g. a scan over the stage's layer slice)
can be pipelined.  Reverse-mode AD works through the whole schedule
(ppermute transposes to the opposite shift), so this composes with
jax.grad for training.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


def gpipe(
    fn_stage: Callable,
    stage_params,
    x_microbatches: jax.Array,  # (M, mb, ...) input microbatches
    *,
    n_stages: int,
    axis: str = "pipe",
):
    """Run ``fn_stage`` as a pipeline across ``n_stages`` ranks of
    ``axis``.  Per-rank code (inside shard_map): ``stage_params`` is the
    local stage slice; every rank receives the full microbatch array (the
    first stage consumes it; others ignore).

    Returns the (M, mb, ...) outputs of the LAST stage, replicated across
    the axis (combined with a masked psum)."""
    M = x_microbatches.shape[0]
    stage = lax.axis_index(axis)
    is_first = stage == 0
    is_last = stage == n_stages - 1
    mb_shape = x_microbatches.shape[1:]

    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        buf, acc = carry
        # stage 0 injects microbatch t (when in range); others take the
        # neighbour's output from the previous tick
        mb_idx = jnp.clip(t, 0, M - 1)
        inject = lax.dynamic_index_in_dim(x_microbatches, mb_idx, axis=0,
                                          keepdims=False)
        x = jnp.where(is_first, inject, buf)
        y = fn_stage(stage_params, x)
        # collect on the last stage once the pipe has filled
        out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
        take = is_last & (t >= n_stages - 1)
        acc = lax.dynamic_update_index_in_dim(
            acc, jnp.where(take, y, lax.dynamic_index_in_dim(
                acc, out_idx, axis=0, keepdims=False)), out_idx, axis=0)
        # shift boundary activations to the next stage
        buf = lax.ppermute(y, axis, perm)
        return (buf, acc), None

    buf0 = lax.pvary(jnp.zeros(mb_shape, x_microbatches.dtype), (axis,))
    acc0 = lax.pvary(jnp.zeros((M,) + mb_shape, x_microbatches.dtype),
                     (axis,))
    (_, acc), _ = lax.scan(tick, (buf0, acc0),
                           jnp.arange(M + n_stages - 1))
    # only the last stage holds real outputs; make them replicated
    acc = jnp.where(is_last, acc, 0.0)
    return lax.psum(acc, axis)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: idle-tick share of the schedule."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
