"""Training driver.

Runs on whatever devices exist (a laptop CPU for --smoke, a v5e pod when
launched under the production mesh).  Composes: config registry, data
pipeline, shard_map train step, AdamW (+ optional LP trust-region
clipping — the paper's solver in the training loop), checkpointing with
resume, heartbeat + straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, smoke_config
from repro.data.pipeline import TokenSource, for_model
from repro.ckpt.checkpoint import Checkpointer
from repro.launch.elastic import Heartbeat, StragglerMonitor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch import steps as steps_mod
from repro.optim import AdamW


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU runs")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--lp-clip", action="store_true",
                    help="LP trust-region update scaling (the paper's "
                         "batch solver inside the optimizer)")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="data,model (default: all local devices as data)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_host_mesh(d, m)
    else:
        mesh = make_host_mesh(len(jax.devices()), 1)

    optimizer = AdamW(lr=args.lr)
    prog = steps_mod.make_train_step(
        cfg, mesh, optimizer, global_batch=args.batch,
        lp_clip=args.lp_clip)
    step_fn = prog.jit()

    params = prog.model.init(jax.random.key(args.seed))
    opt_state = optimizer.init(params)
    extra = {}

    dcfg = for_model(cfg, args.seq, args.batch, seed=args.seed,
                     source=args.data, path=args.data_path)
    src = TokenSource(dcfg)

    start = 0
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.load((params, opt_state))
        start = int(meta.get("next_step", 0))
        print(f"[train] resumed from step {start}")

    hb = Heartbeat(args.heartbeat) if args.heartbeat else None
    strag = StragglerMonitor()

    t_last = time.time()
    for step in range(start, args.steps):
        batch = src.global_batch(step)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        if cfg.dtype == "bfloat16":
            for k in ("patches", "frames"):
                if k in batch:
                    batch[k] = batch[k].astype(jax.numpy.bfloat16)
        params, opt_state, metrics, extra = step_fn(
            params, opt_state, batch, extra)
        dt = time.time() - t_last
        t_last = time.time()
        slow = strag.record(step, dt)
        if hb is not None:
            hb.beat(step)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            s1 = float(metrics["lp_s1"])
            print(f"[train] step {step:6d} loss {loss:8.4f} "
                  f"dt {dt*1e3:8.1f}ms lp_s1 {s1:.3f}"
                  + ("  STRAGGLER" if slow else ""), flush=True)
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state),
                      extra={"next_step": step + 1})
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt_state),
                  extra={"next_step": args.steps}, blocking=True)
    print(f"[train] done; median step {strag.median*1e3:.1f}ms, "
          f"{len(strag.flagged)} straggler steps")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
