import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract memory / cost / collective analysis.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first backend init, and only the dry-run should
see 512 placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, applicable, input_specs
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.launch import steps
from repro.optim import AdamW
from repro.roofline import (Roofline, collective_bytes, from_compiled,
                            fused_hbm_estimate, model_flops_estimate)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def _abstract(f, *args, **kw):
    return jax.eval_shape(lambda: f(*args, **kw))


def _compile_step(cfg, shape, mesh, step_kwargs=None):
    """Lower + compile the full step for (cfg, shape) on mesh."""
    kw = dict(step_kwargs or {})
    if shape.kind == "train":
        kw.pop("weight_resident", None)
        prog = steps.make_train_step(cfg, mesh, AdamW(),
                                     global_batch=shape.batch, **kw)
        params = _abstract(prog.model.init, jax.random.key(0))
        opt = _abstract(AdamW().init, params)
        batch = input_specs(cfg, shape)
        lowered = prog.jit().lower(params, opt, batch, {})
    elif shape.kind == "prefill":
        prog = steps.make_prefill_step(cfg, mesh, global_batch=shape.batch,
                                       **kw)
        params = _abstract(prog.model.init, jax.random.key(0))
        batch = input_specs(cfg, shape)
        lowered = prog.jit().lower(params, batch)
    else:  # decode
        prog = steps.make_decode_step(cfg, mesh, global_batch=shape.batch,
                                      **kw)
        params = _abstract(prog.model.init, jax.random.key(0))
        batch = input_specs(cfg, shape)
        cache = _abstract(prog.model.init_cache, shape.batch, shape.seq)
        lowered = prog.jit().lower(params, batch, cache)
    return lowered.compile()


def _cost_tuple(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _probe_cfgs(cfg):
    """Reduced-depth unrolled probe variants + the depth unit count.

    XLA's cost_analysis counts a while body once regardless of trip count,
    so we compile depth-1 and depth-2 *unrolled* variants and extrapolate
    total(L) = c1 + (units-1) * (c2 - c1).  Attention probes use the dense
    path (identical flops to the chunked path; its score-matrix HBM
    traffic is an honest unfused upper bound, see EXPERIMENTS.md)."""
    import dataclasses as _dc
    probe = dict(scan_unroll=True, flash_threshold=1 << 30, remat=False)
    if cfg.family == "hybrid":
        per = cfg.hybrid_period
        units = cfg.n_layers // per
        c1 = _dc.replace(cfg, n_layers=per, **probe)
        c2 = _dc.replace(cfg, n_layers=2 * per, **probe)
    elif cfg.family == "encdec":
        units = cfg.n_layers
        c1 = _dc.replace(cfg, n_layers=1, enc_layers=1, **probe)
        c2 = _dc.replace(cfg, n_layers=2, enc_layers=2, **probe)
    else:
        units = cfg.n_layers
        c1 = _dc.replace(cfg, n_layers=1, **probe)
        c2 = _dc.replace(cfg, n_layers=2, **probe)
    return c1, c2, units


def _probe_roofline(cfg, shape, mesh, chips, step_kwargs=None):
    c1, c2, units = _probe_cfgs(cfg)
    f1, b1, coll1 = _cost_tuple(_compile_step(c1, shape, mesh, step_kwargs))
    f2, b2, coll2 = _cost_tuple(_compile_step(c2, shape, mesh, step_kwargs))
    flops = f1 + (units - 1) * (f2 - f1)
    hbm = b1 + (units - 1) * (b2 - b1)
    ops = set(coll1) | set(coll2)
    coll = {op: coll1.get(op, 0) + (units - 1) *
            (coll2.get(op, 0) - coll1.get(op, 0)) for op in ops}
    mf = model_flops_estimate(cfg, shape.kind, shape.batch, shape.seq)
    mi = mesh_info(mesh)
    fused = fused_hbm_estimate(cfg, shape.kind, shape.batch, shape.seq,
                               mi.model_size, mi.data_size)
    return Roofline(flops=flops, hbm_bytes=hbm,
                    coll_bytes=float(sum(coll.values())), chips=chips,
                    model_flops=mf, coll_by_op=coll, hbm_fused=fused)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                overrides: dict | None = None, verbose: bool = True,
                probe: bool = True, step_kwargs: dict | None = None,
                variant: str = "baseline") -> dict:
    cfg = ARCHS[arch]
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic mixing"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    # 1) the actual dry-run: full-depth scanned graph must compile
    t0 = time.time()
    compiled = _compile_step(cfg, shape, mesh, step_kwargs)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "chips": chips, "status": "ok", "variant": variant,
        "compile_s": round(t_compile, 2), "memory": mem_d,
    }

    # 2) roofline terms from the unrolled depth probes (single-pod only
    #    is required for the table, but cheap enough to always record)
    if probe:
        roof = _probe_roofline(cfg, shape, mesh, chips, step_kwargs)
        rec["roofline"] = roof.as_dict()
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} "
                  f"({'2x16x16' if multi_pod else '16x16'}): "
                  f"compile {t_compile:.1f}s  bottleneck={roof.bottleneck}  "
                  f"frac={roof.roofline_fraction:.3f}")
            print(f"  terms: compute={roof.t_compute*1e3:.2f}ms  "
                  f"memory={roof.t_memory*1e3:.2f}ms  "
                  f"collective={roof.t_collective*1e3:.2f}ms  "
                  f"useful={roof.useful_ratio:.3f}  "
                  f"args/dev={(mem_d['argument_bytes'] or 0)/chips/1e9:.2f}GB")
    elif verbose:
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'2x16x16' if multi_pod else '16x16'}): "
              f"compile {t_compile:.1f}s OK")
    return rec


def dryrun_lp(*, multi_pod: bool = False, batch: int = 1 << 20,
              m: int = 256, method: str = "rgb") -> dict:
    """The paper's own workload on the production mesh."""
    import jax.numpy as jnp
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    prog = steps.make_lp_step(mesh, batch=batch, m=m, method=method)
    bd = {
        "A": jax.ShapeDtypeStruct((batch, m, 2), jnp.float32),
        "b": jax.ShapeDtypeStruct((batch, m), jnp.float32),
        "c": jax.ShapeDtypeStruct((batch, 2), jnp.float32),
        "m_valid": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    t0 = time.time()
    compiled = prog.jit().lower(bd).compile()
    # ~4 flops per (constraint-consideration) + expected 2 ln m resolves
    # of ~12m flops each per problem
    import math
    mf = batch * (4.0 * m + 2 * math.log(max(m, 2)) * 12 * m)
    roof = from_compiled(compiled, chips, mf)
    rec = {"arch": f"lp-{method}", "shape": f"b{batch}_m{m}",
           "multi_pod": multi_pod, "chips": chips, "status": "ok",
           "compile_s": round(time.time() - t0, 2),
           "roofline": roof.as_dict()}
    print(f"[dryrun] lp-{method} b={batch} m={m}: "
          f"bottleneck={roof.bottleneck} frac={roof.roofline_fraction:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on both meshes")
    ap.add_argument("--lp", action="store_true", help="LP-solver dry-run")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    records = []
    if args.lp:
        records.append(dryrun_lp(multi_pod=args.multi_pod))
    elif args.all:
        # the baseline sweep: FSDP serving gathers, conservative (check_rep
        # =False) transposes — the optimized variants are recorded
        # separately by benchmarks/hillclimb.py
        base_kw = {"weight_resident": False}
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    try:
                        # roofline probes on the single-pod mesh only (the
                        # table is single-pod; multi-pod proves sharding)
                        records.append(dryrun_cell(arch, shape,
                                                   multi_pod=mp,
                                                   probe=not mp,
                                                   step_kwargs=base_kw))
                    except Exception as e:  # a failure here is a real bug
                        traceback.print_exc()
                        records.append({"arch": arch, "shape": shape,
                                        "multi_pod": mp, "status": "FAIL",
                                        "error": repr(e)})
    else:
        records.append(dryrun_cell(args.arch, args.shape,
                                   multi_pod=args.multi_pod))

    out = args.out or (RESULTS_DIR / "dryrun.json")
    existing = []
    p = Path(out)
    if p.exists():
        existing = json.loads(p.read_text())
    keyed = {(r["arch"], r["shape"], r.get("multi_pod", False),
              r.get("variant", "baseline")): r for r in existing}
    for r in records:
        keyed[(r["arch"], r["shape"], r.get("multi_pod", False),
               r.get("variant", "baseline"))] = r
    p.write_text(json.dumps(list(keyed.values()), indent=1))
    print(f"wrote {len(records)} records -> {out}")
    n_fail = sum(1 for r in records if r["status"] == "FAIL")
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run cells FAILED")


if __name__ == "__main__":
    main()
