"""Production and test meshes.

Functions, not module-level constants — importing this module never
touches jax device state (required so tests see one CPU device while
dryrun.py sees its 512 forced host devices)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.models.common import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).  Multi-pod: 2 pods =
    512 chips with a leading "pod" axis (outer data / hierarchical
    all-reduce axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests, smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_info(mesh) -> MeshInfo:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    data_axes = tuple(n for n in names if n != "model")
    data_size = 1
    for n in data_axes:
        data_size *= sizes[n]
    return MeshInfo(model_axis="model", data_axes=data_axes,
                    model_size=sizes.get("model", 1), data_size=data_size,
                    bound=True)


def batch_axes(mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """The data axes a global batch can shard over (None -> replicate,
    e.g. batch=1 long-context decode)."""
    mi = mesh_info(mesh)
    if batch % mi.data_size == 0:
        return mi.data_axes
    # try the innermost data axis alone (e.g. batch 16 on a 2x16 data mesh)
    last = mi.data_axes[-1]
    size = dict(zip(mesh.axis_names, mesh.devices.shape))[last]
    if batch % size == 0:
        return (last,)
    return None
