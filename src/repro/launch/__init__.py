"""Mesh construction, step builders, dry-run and training drivers."""
