"""Mesh-independent checkpointing with async save and atomic commit.

Layout (one directory per step):

    <root>/step_000042/
        manifest.json     # step, flat param paths, shapes, dtypes, meta
        <path>.npy        # one .npy per leaf (paths are slash-joined)
    <root>/LATEST         # atomically-updated pointer file

Properties needed at cluster scale:
  * **mesh independence** — leaves are saved as full logical arrays
    (gathered), so a restart may use a different mesh/topology: load()
    just feeds `jax.device_put(leaf, NamedSharding(new_mesh, spec))`
    (elastic rescaling).  Parameter shapes are mesh-independent by
    construction (models.common.CANONICAL_TP).
  * **atomicity** — writes go to `step_N.tmp/` and are renamed into
    place; the LATEST pointer is updated last via atomic rename.  A crash
    mid-save never corrupts the previous checkpoint (restart-safe).
  * **async** — save() returns immediately; a daemon thread serialises.
    wait() joins (called before the next save or at exit).
  * the data-pipeline cursor and RNG state ride along in the manifest,
    so restart resumes the exact token stream.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any], like):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            vals = [rec(f"{prefix}/{i}" if prefix else str(i), v)
                    for i, v in enumerate(node)]
            if hasattr(node, "_fields"):  # NamedTuple
                return type(node)(*vals)
            return type(node)(vals)
        return flat[prefix]
    return rec("", like)


class Checkpointer:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, params, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot (device->host copy happens HERE, synchronously cheap);
        disk IO happens on the daemon thread unless blocking=True."""
        self.wait()
        flat = _flatten(params)
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {"step": step, "extra": extra or {},
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()}}

        def work():
            self._write(step, host, meta)

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for k, v in host.items():
            fp = tmp / (k.replace("/", "__") + ".npy")
            np.save(fp, v)
        (tmp / "manifest.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        # atomic LATEST pointer
        ptr = self.root / "LATEST.tmp"
        ptr.write_text(final.name)
        os.rename(ptr, self.root / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.root.glob("step_????????")
                       if p.is_dir())
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- load ---------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.root / name / "manifest.json").exists():
            return None
        return int(name.split("_")[1])

    def load(self, like, step: Optional[int] = None,
             shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like``; if ``shardings`` (a
        matching tree of NamedSharding) is given, leaves are device_put
        with it — this is where elastic re-sharding onto a NEW mesh
        happens."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = self.root / f"step_{step:08d}"
        meta = json.loads((d / "manifest.json").read_text())
        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else None
        out = {}
        for k, ref in flat_like.items():
            arr = np.load(d / (k.replace("/", "__") + ".npy"))
            if arr.dtype.kind == "V":
                # numpy round-trips ml_dtypes (bfloat16 etc.) as void;
                # reinterpret via the dtype recorded in the manifest
                import ml_dtypes  # noqa: F401
                arr = arr.view(np.dtype(meta["leaves"][k]["dtype"]))
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            if flat_sh is not None:
                out[k] = jax.device_put(arr, flat_sh[k])
            else:
                out[k] = jax.numpy.asarray(arr)
        return _unflatten(out, like), meta["extra"]
