"""Row-form PDHG primitives for batched 2-D LPs.

Everything here operates on the packed component rows ``(a_x, a_y, b)``
— the same SoA layout :class:`~repro.core.packed.PackedLPBatch` carries
and the Seidel backends consume — so the first-order backend is
matrix-free by construction: the only contact with the constraint
matrix is ``A @ x`` (two fused multiply-adds over rows) and
``A^T @ y`` (two row reductions).  That is what lets ``m`` grow into
the thousands where the O(m^2)-ish incremental solvers stop scaling.

Problems are the batch axis; every function is batched over ``(B, ...)``
with no vmap — shapes are ``ax/ay/bb (B, m)``, ``x/c (B, 2)``,
``y (B, m)``.

The LP solved is the repo-wide contract: maximise ``c @ x`` subject to
``A x <= b`` and the box ``|x_i| <= M``.  The box is handled by
projection (not by the four explicit rows the Seidel solvers append),
so the primal iterate is always box-feasible and the dual variable for
the box never needs to be materialised — its reduced cost
``lambda = c - A^T y`` is scored against the normal cone of the box at
``x`` instead (:func:`kkt_residuals_rows`).
"""
from __future__ import annotations

import jax.numpy as jnp

# Guard for divisions / norms of quantities that may be exactly zero
# (padding problems, zero objectives).
EPS_GUARD = 1e-12


def matvec_rows(ax, ay, x):
    """``A @ x`` per problem: ``ax/ay (B, m)``, ``x (B, 2) -> (B, m)``."""
    return ax * x[:, 0:1] + ay * x[:, 1:2]


def rmatvec_rows(ax, ay, y):
    """``A^T @ y`` per problem: ``y (B, m) -> (B, 2)``."""
    return jnp.stack([jnp.sum(ax * y, axis=-1),
                      jnp.sum(ay * y, axis=-1)], axis=-1)


def spectral_norm_rows(ax, ay):
    """Exact ``||A||_2`` per problem, ``(B,)``.

    With only two columns the Gram matrix ``A^T A`` is 2x2, so the top
    eigenvalue has a closed form — no power iteration, no Frobenius
    over-estimate (which would cost a ~sqrt(m/2) step-size haircut at
    large m).
    """
    g11 = jnp.sum(ax * ax, axis=-1)
    g22 = jnp.sum(ay * ay, axis=-1)
    g12 = jnp.sum(ax * ay, axis=-1)
    half = 0.5 * (g11 + g22)
    rad = jnp.sqrt(jnp.maximum(0.25 * (g11 - g22) ** 2 + g12 * g12, 0.0))
    return jnp.sqrt(jnp.maximum(half + rad, 0.0))


def pdhg_step(x, y, ax, ay, bb, c, tau, sigma, M):
    """One extrapolated PDHG iteration (Chambolle–Pock / PDLP form).

    Primal ascent on the reduced cost with projection onto the box,
    then dual ascent on the extrapolated residual with projection onto
    ``y >= 0``::

        x+ = clip(x + tau * (c - A^T y), -M, M)
        y+ = max(0, y + sigma * (A (2 x+ - x) - b))

    ``tau``/``sigma`` are per-problem ``(B,)`` step sizes (they carry
    the primal weight omega, which the restart driver adapts).
    """
    lam = c - rmatvec_rows(ax, ay, y)
    x_new = jnp.clip(x + tau[:, None] * lam, -M, M)
    x_bar = 2.0 * x_new - x
    y_new = jnp.maximum(y + sigma[:, None] * (matvec_rows(ax, ay, x_bar)
                                              - bb), 0.0)
    return x_new, y_new


def kkt_residuals_rows(x, y, ax, ay, bb, c, *, M, b_scale, c_scale,
                       bound_tol):
    """Relative KKT residuals of ``(x, y)`` per problem.

    Returns ``(pres, dres, compl)``, each ``(B,)``:

    * ``pres`` — primal infeasibility ``||(A x - b)_+||_inf`` over
      ``b_scale = 1 + ||b||_inf``;
    * ``dres`` — stationarity: the distance of the reduced cost
      ``lambda = c - A^T y`` from the normal cone of the box at ``x``
      (a component at a bound may carry a reduced cost of the matching
      sign; an interior component must have zero reduced cost), over
      ``c_scale = 1 + ||c||_inf``;
    * ``compl`` — constraint complementarity ``sum_h y_h |b_h - a_h x|``
      over ``1 + |c @ x|``.

    Deliberately *not* the textbook duality gap ``D(y) - P(x)``: with
    the box folded into the dual objective the gap carries an
    ``M * ||lambda||_1`` term, and at ``M = 1e4`` that amplifies float32
    rounding in ``lambda`` (~1e-6) into an irreducible ~1e-2 absolute
    gap floor.  The normal-cone split certifies the same KKT system
    without the amplification, so float32 solves can actually reach
    their tolerance.
    """
    s = bb - matvec_rows(ax, ay, x)                       # slack (B, m)
    pres = jnp.max(jnp.maximum(-s, 0.0), axis=-1) / b_scale
    lam = c - rmatvec_rows(ax, ay, y)
    at_hi = x >= (M - bound_tol)
    at_lo = x <= -(M - bound_tol)
    dres_c = jnp.where(at_hi, jnp.maximum(-lam, 0.0),
                       jnp.where(at_lo, jnp.maximum(lam, 0.0),
                                 jnp.abs(lam)))
    dres = jnp.max(dres_c, axis=-1) / c_scale
    obj = jnp.einsum("bd,bd->b", c, x)
    compl = jnp.sum(y * jnp.abs(s), axis=-1) / (1.0 + jnp.abs(obj))
    return pres, dres, compl
