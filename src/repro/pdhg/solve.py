"""Restarted PDHG driver for batched 2-D LPs (cuPDLP-style).

The iteration runs in fixed blocks of ``iter_block`` steps under a
``lax.while_loop``; residuals, restarts and convergence masks are only
evaluated at block boundaries, so the hot loop is nothing but fused
row-form multiply-adds.  Per problem the driver keeps

* a running average of the iterates since the last restart (the
  restart *candidate* is whichever of {current, average} has the lower
  normalized KKT score — averaging is what restores the linear rate on
  LPs);
* the best iterate seen so far (returned at the end, so a solve
  interrupted by ``max_iters`` still reports its best certificate);
* the primal weight ``omega`` (``tau = eta/omega``, ``sigma =
  eta*omega``), re-balanced on every restart from the observed
  primal/dual movement — cuPDLP's smoothed update, with the per-restart
  step bounded (``OMEGA_STEP_CLAMP``) so one noisy cycle cannot swing
  the weight by orders of magnitude and freeze the primal.

Restarts fire per problem on *sufficient decay* of the KKT score
(``<= RESTART_BETA *`` the score at the last restart, baselined at the
actual starting point, not infinity) or on the *artificial* period
``restart_period`` (0 disables the periodic trigger).  A cycle whose
candidate score blows up past ``DIVERGE_FACTOR *`` the best score seen
recovers by restarting from the best (x, y) pair with ``omega`` pulled
back toward its initial value.  Converged problems freeze: their
updates are masked out, so a batch only pays until its slowest member
converges or ``max_iters`` is hit.

Two 2-D-specific moves make small ragged batches robust, not just the
large well-conditioned ones PDHG is built for:

* each problem is solved in rescaled coordinates ``x' = x / s`` with
  ``s = max(1, ||b||_inf)`` (the 2-D stand-in for cuPDLP's Ruiz
  scaling) — generators whose optimum sits O(100) box-units from the
  origin otherwise need O(distance) iterations just to travel there;
* a *crossover polish* after the loop (the 2-D analogue of PDLP's
  basis crossover): the two highest-dual rows are intersected with
  each other and with the four box faces, and the best feasible vertex
  replaces the iterate when it improves it.  On narrow-wedge LPs
  (near-antiparallel active normals, Hoffman constant in the hundreds)
  the iterate crawls but its top duals already identify the active
  faces, so the polish lands the exact vertex.

Feasibility classification matches the Seidel backends on 2-D inputs:
an infeasible LP's primal residual is bounded away from zero, so it
rides to ``max_iters`` and is classified by its best residual;
"unbounded" LPs saturate the same ``M`` box the dense backends use, so
both report the box-corner optimum.  Unlike Seidel, which is exact at
convergence, PDHG answers carry a first-order tolerance: ``tol``
bounds the *relative KKT residuals* of the returned point, not the
number of correct digits of the objective.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import oneD
from repro.core.lp import LPBatch, LPSolution
from repro.core.packed import PackedLPBatch
from repro.core.seidel import DEFAULT_M
from repro.pdhg.iteration import (EPS_GUARD, kkt_residuals_rows,
                                  pdhg_step, spectral_norm_rows)

# Block/restart defaults; the measured tuning table overrides per shape
# (see repro.tune.space PDHG_ITER_BLOCKS / PDHG_RESTART_PERIODS).
DEFAULT_ITER_BLOCK = 64
DEFAULT_RESTART_PERIOD = 1024

# Sufficient-decay factor for adaptive restarts (cuPDLP uses ~0.2).
RESTART_BETA = 0.2

# Step-size safety margin: tau * sigma * ||A||^2 = STEP_SAFETY^2 < 1.
STEP_SAFETY = 0.9

# Primal-weight clamp — omega updates are multiplicative, keep them sane.
OMEGA_MIN, OMEGA_MAX = 1e-6, 1e6

# Largest multiplicative omega change one restart may apply.
OMEGA_STEP_CLAMP = 4.0

# A cycle whose candidate KKT score exceeds this multiple of the best
# score seen AND the absolute floor is treated as diverging and
# recovers from the best pair.  The floor keeps recovery an emergency
# brake: near convergence the (nonmonotone) score routinely pops an
# order of magnitude above a ~1e-8 best, and resetting omega there
# would stall the endgame.
DIVERGE_FACTOR = 10.0
DIVERGE_KKT_FLOOR = 0.5

# Feasibility classification threshold on the *relative* primal
# residual.  Converged problems sit at <= tol; infeasible generators in
# this repo sit O(1e-1) away — anything in between means "ran out of
# iterations on a feasible problem", which we classify optimistically
# only up to this floor (comparable to oneD.EPS_FEAS's scale).
FEAS_EPS_REL = 1e-4


def default_tol(dtype) -> float:
    """Relative KKT tolerance by precision: float32 stops where its
    rounding floor starts; float64 matches the 1e-8 cuPDLP default."""
    return 1e-8 if jnp.dtype(dtype) == jnp.dtype("float64") else 1e-4


def default_max_iters(dtype) -> int:
    return 100_000 if jnp.dtype(dtype) == jnp.dtype("float64") else 20_000


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PDHGStats:
    """Per-problem convergence certificate of a PDHG solve.

    Residuals are *relative* and measured on the internally rescaled
    problem (``b`` and the box divided by ``max(1, ||b||_inf)``), at
    the returned (possibly crossover-polished) primal point paired
    with the best dual iterate."""

    iterations: jax.Array   # (B,) int32 iterations to convergence/stop
    restarts: jax.Array     # (B,) int32 restarts fired
    primal_res: jax.Array   # (B,) relative primal residual
    dual_res: jax.Array     # (B,) relative dual (stationarity) residual
    compl: jax.Array        # (B,) relative complementarity residual
    kkt: jax.Array          # (B,) max of the three
    converged: jax.Array    # (B,) bool: some iterate reached kkt <= tol


def _solve_rows(ax, ay, bb, c, m_valid, *, M: float,
                tol: Optional[float], max_iters: Optional[int],
                iter_block: Optional[int],
                restart_period: Optional[int]
                ) -> Tuple[LPSolution, PDHGStats]:
    """The driver over component rows; all knobs are static Python
    scalars (None -> dtype-based default)."""
    B, m = ax.shape
    dt = ax.dtype
    tol = float(default_tol(dt) if tol is None else tol)
    max_iters = int(default_max_iters(dt) if max_iters is None
                    else max_iters)
    iter_block = int(DEFAULT_ITER_BLOCK if iter_block is None
                     else iter_block)
    restart_period = int(DEFAULT_RESTART_PERIOD if restart_period is None
                         else restart_period)
    Mv = jnp.asarray(M, dt)
    c = c.astype(dt)
    m_valid = m_valid.reshape(-1)

    if m == 0:
        # No constraints at all: the optimum is the preferred box corner
        # (same tie-break as the Seidel backends' start point).
        x = jax.vmap(lambda ci: oneD.box_corner(ci, Mv))(c)
        zeros = jnp.zeros((B,), dt)
        sol = LPSolution(x=x, feasible=jnp.ones((B,), bool),
                         objective=jnp.einsum("bd,bd->b", c, x))
        stats = PDHGStats(iterations=jnp.zeros((B,), jnp.int32),
                          restarts=jnp.zeros((B,), jnp.int32),
                          primal_res=zeros, dual_res=zeros, compl=zeros,
                          kkt=zeros, converged=jnp.ones((B,), bool))
        return sol, stats

    # Rows at or past m_valid are forced to the neutral constraint
    # (0, 0, 1) so ragged batches match the Seidel masking semantics
    # even if a caller left garbage past the valid count.  The neutral
    # row is then exactly inert: it contributes nothing to A x or
    # A^T y, and its dual component projects to (and stays at) zero.
    keep = jnp.arange(m)[None, :] < m_valid[:, None]
    ax = jnp.where(keep, ax, 0.0).astype(dt)
    ay = jnp.where(keep, ay, 0.0).astype(dt)
    bb = jnp.where(keep, bb, 1.0).astype(dt)

    # 2-D Ruiz-style rescale: solve for x' = x / s with
    # s = max(1, ||b||_inf); an optimum O(||b||) box-units out becomes
    # O(1) travel for the iteration, and the residuals below are
    # measured on this rescaled problem.
    s_scale = jnp.maximum(
        1.0, jnp.max(jnp.where(keep, jnp.abs(bb), 0.0), axis=-1)
    ).astype(dt)
    bb = bb / s_scale[:, None]
    Ms = (Mv / s_scale)[:, None]                        # (B, 1) box

    # Per-problem geometry: exact ||A||_2 -> step scale eta; primal
    # weight omega seeded from the objective/rhs balance (PDLP init).
    norm_A = spectral_norm_rows(ax, ay)
    eta = STEP_SAFETY / jnp.maximum(norm_A, EPS_GUARD)
    norm_c = jnp.linalg.norm(c, axis=-1)
    norm_b = jnp.linalg.norm(jnp.where(keep, bb, 0.0), axis=-1)
    omega0 = jnp.clip(
        jnp.where((norm_c > EPS_GUARD) & (norm_b > EPS_GUARD),
                  norm_c / jnp.maximum(norm_b, EPS_GUARD), 1.0),
        OMEGA_MIN, OMEGA_MAX).astype(dt)
    b_scale = 1.0 + jnp.max(jnp.where(keep, jnp.abs(bb), 0.0), axis=-1)
    c_scale = 1.0 + jnp.max(jnp.abs(c), axis=-1)
    bound_tol = jnp.asarray(1e-6, dt) * Ms

    def kkt_of(x, y):
        pres, dres, compl = kkt_residuals_rows(
            x, y, ax, ay, bb, c, M=Ms, b_scale=b_scale, c_scale=c_scale,
            bound_tol=bound_tol)
        return pres, dres, compl, jnp.maximum(pres,
                                              jnp.maximum(dres, compl))

    x0 = jnp.zeros((B, 2), dt)
    y0 = jnp.zeros((B, m), dt)
    big = jnp.asarray(jnp.finfo(dt).max, dt)
    _, _, _, kkt0 = kkt_of(x0, y0)
    state = dict(
        it=jnp.asarray(0, jnp.int32),
        x=x0, y=y0,
        # running average since last restart
        x_sum=jnp.zeros_like(x0), y_sum=jnp.zeros_like(y0),
        n_avg=jnp.zeros((B,), dt),
        # last-restart snapshot (omega update + decay baseline; the
        # baseline starts at the actual initial score — an infinite
        # baseline would fire the decay trigger on the very first
        # block and let one noisy cycle set omega)
        x_rs=x0, y_rs=y0, kkt_rs=kkt0,
        cycle=jnp.zeros((B,), jnp.int32),
        omega=omega0,
        active=jnp.ones((B,), bool),
        # best-so-far certificate
        best_x=x0, best_y=y0, best_kkt=jnp.full((B,), big, dt),
        best_pres=jnp.full((B,), big, dt),
        best_dres=jnp.full((B,), big, dt),
        best_compl=jnp.full((B,), big, dt),
        iters_done=jnp.zeros((B,), jnp.int32),
        restarts=jnp.zeros((B,), jnp.int32),
    )

    def cond(s):
        return (s["it"] < max_iters) & jnp.any(s["active"])

    def body(s):
        act = s["active"]
        actc = act[:, None]
        tau = eta / s["omega"]
        sigma = eta * s["omega"]

        def inner(_, carry):
            x, y, x_sum, y_sum, n_avg = carry
            x_new, y_new = pdhg_step(x, y, ax, ay, bb, c, tau, sigma, Ms)
            x = jnp.where(actc, x_new, x)
            y = jnp.where(actc, y_new, y)
            x_sum = x_sum + jnp.where(actc, x, 0.0)
            y_sum = y_sum + jnp.where(actc, y, 0.0)
            n_avg = n_avg + act
            return x, y, x_sum, y_sum, n_avg

        x, y, x_sum, y_sum, n_avg = lax.fori_loop(
            0, iter_block, inner,
            (s["x"], s["y"], s["x_sum"], s["y_sum"], s["n_avg"]))
        cycle = s["cycle"] + jnp.where(act, iter_block, 0)

        # Candidate = better-scored of {current iterate, cycle average}.
        pres_c, dres_c, compl_c, kkt_c = kkt_of(x, y)
        n = jnp.maximum(n_avg, 1.0)
        x_avg = x_sum / n[:, None]
        y_avg = y_sum / n[:, None]
        pres_a, dres_a, compl_a, kkt_a = kkt_of(x_avg, y_avg)
        use_avg = kkt_a < kkt_c
        uac = use_avg[:, None]
        x_cand = jnp.where(uac, x_avg, x)
        y_cand = jnp.where(uac, y_avg, y)
        kkt_cand = jnp.where(use_avg, kkt_a, kkt_c)
        pres_cand = jnp.where(use_avg, pres_a, pres_c)
        dres_cand = jnp.where(use_avg, dres_a, dres_c)
        compl_cand = jnp.where(use_avg, compl_a, compl_c)

        better = act & (kkt_cand < s["best_kkt"])
        best_x = jnp.where(better[:, None], x_cand, s["best_x"])
        best_y = jnp.where(better[:, None], y_cand, s["best_y"])
        best_kkt = jnp.where(better, kkt_cand, s["best_kkt"])
        best_pres = jnp.where(better, pres_cand, s["best_pres"])
        best_dres = jnp.where(better, dres_cand, s["best_dres"])
        best_compl = jnp.where(better, compl_cand, s["best_compl"])

        newly = act & (kkt_cand <= tol)
        iters_done = jnp.where(act, s["it"] + iter_block, s["iters_done"])
        active = act & ~newly

        # A blown-up cycle recovers from the best pair seen; otherwise
        # restart on sufficient decay or on the artificial period.
        recover = active & (kkt_cand > jnp.maximum(
            DIVERGE_FACTOR * best_kkt, DIVERGE_KKT_FLOOR))
        decay = kkt_cand <= RESTART_BETA * s["kkt_rs"]
        if restart_period:
            decay = decay | (cycle >= restart_period)
        do_rs = active & (decay | recover)
        rsc = do_rs[:, None]

        # cuPDLP's smoothed primal-weight update from the observed
        # movement over the finished restart cycle, bounded to one
        # OMEGA_STEP_CLAMP factor per restart; a recovery instead pulls
        # omega back toward its initial value.
        dx = jnp.linalg.norm(x_cand - s["x_rs"], axis=-1)
        dy = jnp.linalg.norm(y_cand - s["y_rs"], axis=-1)
        ok = (dx > EPS_GUARD) & (dy > EPS_GUARD)
        omega_prop = jnp.exp(
            0.5 * jnp.log(jnp.maximum(dy, EPS_GUARD)
                          / jnp.maximum(dx, EPS_GUARD))
            + 0.5 * jnp.log(s["omega"]))
        omega_prop = jnp.clip(omega_prop,
                              s["omega"] / OMEGA_STEP_CLAMP,
                              s["omega"] * OMEGA_STEP_CLAMP)
        omega_rs = jnp.where(ok, omega_prop, s["omega"])
        omega_rec = jnp.sqrt(s["omega"] * omega0)
        omega = jnp.where(do_rs,
                          jnp.where(recover, omega_rec, omega_rs),
                          s["omega"])
        omega = jnp.clip(omega, OMEGA_MIN, OMEGA_MAX)

        rec_c = recover[:, None]
        x_t = jnp.where(rec_c, best_x, x_cand)
        y_t = jnp.where(rec_c, best_y, y_cand)
        kkt_t = jnp.where(recover, best_kkt, kkt_cand)
        x = jnp.where(rsc, x_t, x)
        y = jnp.where(rsc, y_t, y)
        x_rs = jnp.where(rsc, x_t, s["x_rs"])
        y_rs = jnp.where(rsc, y_t, s["y_rs"])
        kkt_rs = jnp.where(do_rs, kkt_t, s["kkt_rs"])
        reset = do_rs | newly
        rc = reset[:, None]
        x_sum = jnp.where(rc, 0.0, x_sum)
        y_sum = jnp.where(rc, 0.0, y_sum)
        n_avg = jnp.where(reset, 0.0, n_avg)
        cycle = jnp.where(do_rs, 0, cycle)

        return dict(
            it=s["it"] + iter_block,
            x=x, y=y, x_sum=x_sum, y_sum=y_sum, n_avg=n_avg,
            x_rs=x_rs, y_rs=y_rs, kkt_rs=kkt_rs, cycle=cycle,
            omega=omega, active=active,
            best_x=best_x, best_y=best_y, best_kkt=best_kkt,
            best_pres=best_pres, best_dres=best_dres,
            best_compl=best_compl, iters_done=iters_done,
            restarts=s["restarts"] + do_rs.astype(jnp.int32),
        )

    s = lax.while_loop(cond, body, state)

    feas_eps = max(FEAS_EPS_REL, tol)
    x_it = s["best_x"]
    y_it = s["best_y"]

    # -- crossover polish (2-D basis identification) ------------------
    # Intersect the two highest-dual rows with each other and with the
    # four box faces (15 candidate vertices); the best feasible one
    # replaces the iterate when it improves it.  On narrow-wedge LPs
    # the iterate converges at the Hoffman rate (slow) but the top
    # duals already name the active faces, so this lands the vertex.
    if m >= 2:
        _, top = lax.top_k(y_it, 2)                      # (B, 2)
    else:
        top = jnp.zeros((B, 2), jnp.int32)
    axt = jnp.take_along_axis(ax, top, axis=1)           # (B, 2)
    ayt = jnp.take_along_axis(ay, top, axis=1)
    bt = jnp.take_along_axis(bb, top, axis=1)
    one = jnp.ones((B,), dt)
    zero = jnp.zeros((B,), dt)
    Msf = Ms[:, 0]
    nx = jnp.stack([axt[:, 0], axt[:, 1], one, -one, zero, zero], 1)
    ny = jnp.stack([ayt[:, 0], ayt[:, 1], zero, zero, one, -one], 1)
    rr = jnp.stack([bt[:, 0], bt[:, 1], Msf, Msf, Msf, Msf], 1)
    pair_i = jnp.array([i for i in range(6) for _ in range(i + 1, 6)])
    pair_j = jnp.array([j for i in range(6) for j in range(i + 1, 6)])
    n1x, n1y, r1 = nx[:, pair_i], ny[:, pair_i], rr[:, pair_i]
    n2x, n2y, r2 = nx[:, pair_j], ny[:, pair_j], rr[:, pair_j]
    det = n1x * n2y - n1y * n2x                          # (B, 15)
    det_guard = 100.0 * jnp.finfo(dt).eps * jnp.maximum(
        jnp.sqrt((n1x ** 2 + n1y ** 2) * (n2x ** 2 + n2y ** 2)),
        EPS_GUARD)
    good = jnp.abs(det) > det_guard
    det_safe = jnp.where(good, det, 1.0)
    vx = (r1 * n2y - r2 * n1y) / det_safe                # (B, 15)
    vy = (n1x * r2 - n2x * r1) / det_safe
    viols = []
    for k in range(vx.shape[1]):
        rowv = jnp.max(jnp.maximum(
            ax * vx[:, k:k + 1] + ay * vy[:, k:k + 1] - bb, 0.0), axis=1)
        boxv = jnp.maximum(jnp.maximum(jnp.abs(vx[:, k]),
                                       jnp.abs(vy[:, k])) - Msf, 0.0)
        viols.append(jnp.maximum(rowv, boxv))
    pres_v = jnp.stack(viols, 1) / b_scale[:, None]      # (B, 15)
    valid = good & (pres_v <= feas_eps)
    obj_v = c[:, 0:1] * vx + c[:, 1:2] * vy
    obj_masked = jnp.where(valid, obj_v, -big)
    kbest = jnp.argmax(obj_masked, axis=1)
    obj_pol = jnp.take_along_axis(obj_masked, kbest[:, None], 1)[:, 0]
    x_pol = jnp.stack(
        [jnp.take_along_axis(vx, kbest[:, None], 1)[:, 0],
         jnp.take_along_axis(vy, kbest[:, None], 1)[:, 0]], axis=-1)
    feas_it = s["best_pres"] <= feas_eps
    obj_it = jnp.einsum("bd,bd->b", c, x_it)
    # accept only a *meaningful* improvement so a converged iterate is
    # not churned by one-ulp vertex differences
    margin = 8.0 * jnp.finfo(dt).eps * (1.0 + jnp.abs(obj_it))
    improve = jnp.any(valid, axis=1) & (
        ~feas_it | (obj_pol > obj_it + margin))
    x_fin = jnp.where(improve[:, None], x_pol, x_it)

    pres_f, dres_f, compl_f, kkt_f = kkt_of(x_fin, y_it)
    x_out = x_fin * s_scale[:, None]                     # unscale
    sol = LPSolution(
        x=x_out,
        feasible=pres_f <= feas_eps,
        objective=jnp.einsum("bd,bd->b", c, x_out),
    )
    stats = PDHGStats(
        iterations=s["iters_done"], restarts=s["restarts"],
        primal_res=pres_f, dual_res=dres_f,
        compl=compl_f, kkt=kkt_f,
        converged=(kkt_f <= tol) | (s["best_kkt"] <= tol))
    return sol, stats


# -- public entry points ---------------------------------------------------

def solve_pdhg(batch: LPBatch, *, M: float = DEFAULT_M,
               tol: Optional[float] = None,
               max_iters: Optional[int] = None,
               iter_block: Optional[int] = None,
               restart_period: Optional[int] = None) -> LPSolution:
    """Solve an AoS :class:`LPBatch` with restarted PDHG."""
    sol, _ = _solve_rows(batch.A[..., 0], batch.A[..., 1], batch.b,
                         batch.c, batch.m_valid, M=M, tol=tol,
                         max_iters=max_iters, iter_block=iter_block,
                         restart_period=restart_period)
    return sol


def solve_pdhg_packed(pb: PackedLPBatch, *, M: float = DEFAULT_M,
                      tol: Optional[float] = None,
                      max_iters: Optional[int] = None,
                      iter_block: Optional[int] = None,
                      restart_period: Optional[int] = None) -> LPSolution:
    """The packed fast path: consume ``PackedLPBatch.L`` rows directly
    (no AoS round-trip inside the trace)."""
    sol, _ = _solve_rows(pb.ax, pb.ay, pb.b, pb.c,
                         pb.m_valid.reshape(-1), M=M, tol=tol,
                         max_iters=max_iters, iter_block=iter_block,
                         restart_period=restart_period)
    return sol


def solve_pdhg_with_stats(batch, *, M: float = DEFAULT_M,
                          tol: Optional[float] = None,
                          max_iters: Optional[int] = None,
                          iter_block: Optional[int] = None,
                          restart_period: Optional[int] = None
                          ) -> Tuple[LPSolution, PDHGStats]:
    """Like :func:`solve_pdhg` / :func:`solve_pdhg_packed` (either
    layout) but also returns the per-problem :class:`PDHGStats`
    certificate — what the tests and the crossover benchmark assert
    convergence on."""
    if isinstance(batch, PackedLPBatch):
        return _solve_rows(batch.ax, batch.ay, batch.b, batch.c,
                           batch.m_valid.reshape(-1), M=M, tol=tol,
                           max_iters=max_iters, iter_block=iter_block,
                           restart_period=restart_period)
    return _solve_rows(batch.A[..., 0], batch.A[..., 1], batch.b,
                       batch.c, batch.m_valid, M=M, tol=tol,
                       max_iters=max_iters, iter_block=iter_block,
                       restart_period=restart_period)
