"""repro.pdhg — restarted first-order (PDHG) backend for batched LP.

Matrix-free primal-dual hybrid gradient over the packed component rows,
with cuPDLP-style averaging, adaptive restarts and primal-weight
rebalancing.  Reached through the unified front end as
``SolverSpec(backend="pdhg")``; import this package directly for the
per-problem convergence certificate (:func:`solve_pdhg_with_stats`).
"""
from repro.pdhg.iteration import (kkt_residuals_rows, matvec_rows,
                                  pdhg_step, rmatvec_rows,
                                  spectral_norm_rows)
from repro.pdhg.solve import (DEFAULT_ITER_BLOCK, DEFAULT_RESTART_PERIOD,
                              FEAS_EPS_REL, PDHGStats, default_max_iters,
                              default_tol, solve_pdhg, solve_pdhg_packed,
                              solve_pdhg_with_stats)

__all__ = [
    "DEFAULT_ITER_BLOCK", "DEFAULT_RESTART_PERIOD", "FEAS_EPS_REL",
    "PDHGStats", "default_max_iters", "default_tol",
    "kkt_residuals_rows", "matvec_rows", "pdhg_step", "rmatvec_rows",
    "solve_pdhg", "solve_pdhg_packed", "solve_pdhg_with_stats",
    "spectral_norm_rows",
]
