"""Core batch 2-D LP library (the paper's contribution, in JAX)."""
from repro.core.lp import (
    LPBatch,
    LPSolution,
    adversarial_lp,
    concat_batches,
    infeasible_lp,
    make_batch,
    normalize_batch,
    pad_batch,
    pad_batch_dim,
    ragged_feasible_lp,
    random_feasible_lp,
    replicated_lp,
    shuffle_batch,
    split_batch,
)
from repro.core.seidel import solve_batch_lp, solve_naive, solve_rgb

__all__ = [
    "LPBatch", "LPSolution", "adversarial_lp", "concat_batches",
    "infeasible_lp", "make_batch", "normalize_batch", "pad_batch",
    "pad_batch_dim", "ragged_feasible_lp", "random_feasible_lp",
    "replicated_lp", "shuffle_batch", "split_batch", "solve_batch_lp",
    "solve_naive", "solve_rgb",
]
