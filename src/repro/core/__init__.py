"""Core batch 2-D LP library (the paper's contribution, in JAX)."""
from repro.core.lp import (
    LPBatch,
    LPSolution,
    adversarial_lp,
    concat_batches,
    infeasible_lp,
    make_batch,
    normalize_batch,
    pad_batch,
    pad_batch_dim,
    ragged_feasible_lp,
    random_feasible_lp,
    replicated_lp,
    shuffle_batch,
    split_batch,
)
from repro.core.packed import (
    PackedLPBatch,
    concat_packed,
    normalize_packed,
    pack,
    pack_call_count,
    pad_packed,
    pad_packed_batch_dim,
    shuffle_packed,
    split_packed,
    unpack,
)
from repro.core.seidel import (solve_naive, solve_naive_packed, solve_rgb,
                               solve_rgb_packed)

__all__ = [
    "LPBatch", "LPSolution", "PackedLPBatch", "adversarial_lp",
    "concat_batches", "concat_packed", "infeasible_lp", "make_batch",
    "normalize_batch", "normalize_packed", "pack", "pack_call_count",
    "pad_batch", "pad_batch_dim", "pad_packed", "pad_packed_batch_dim",
    "ragged_feasible_lp", "random_feasible_lp", "replicated_lp",
    "shuffle_batch", "shuffle_packed", "split_batch", "split_packed",
    "solve_naive", "solve_naive_packed", "solve_rgb",
    "solve_rgb_packed", "unpack",
]
