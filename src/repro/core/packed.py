"""Packed struct-of-arrays constraint layout — the canonical device form.

The paper's central memory claim is that "combining the information
into one extended set of data ensures scattered reads use as much of
each cache line as possible".  :class:`PackedLPBatch` is that layout as
a first-class pytree: constraints live in one block ``L (B, 4, m_pad)``
with rows ``(a_x, a_y, b, 0)`` and the constraint index on the minor
axis (the 128-lane axis on TPU), objectives in ``c (B, 2)`` and the
ragged valid counts in ``m_valid (B, 1)`` (kept 2-D so every kernel
intermediate stays >= 2-D).

``pack``/``unpack`` convert losslessly to and from the AoS
:class:`~repro.core.lp.LPBatch`; every batch utility in ``lp`` has a
packed-native twin here (``normalize_packed``, ``shuffle_packed``,
``pad_packed``, ``pad_packed_batch_dim``, ``concat_packed``,
``split_packed``) computing the *same scalar pipeline*, so a batch
packs once and solves bit-identically to the AoS path — without ever
round-tripping back to AoS.  (For ``shuffle=True`` solves the
bit-identity needs the default ``m_pad == m`` pack: extra constraint
padding — in either layout — changes the shuffle's score-draw shape,
leaving results equal only to the usual order-invariance tolerance.)

``pack`` is the only AoS -> SoA conversion in the tree and counts its
invocations (:func:`pack_call_count`); the serving layer's zero-repack
guarantee is asserted against that counter.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.lp import PAD_B, LPBatch, _row_norms

# AoS -> SoA conversion counter.  Incremented by ``pack`` only (at trace
# time under jit): a hot path that never repacks leaves it untouched.
_PACK_CALLS = 0


def pack_call_count() -> int:
    """Total ``pack`` invocations in this process (trace-time under
    jit).  Diff around a code path to prove it does no AoS -> SoA
    repacking."""
    return _PACK_CALLS


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedLPBatch:
    """A batch of 2-D LPs in the packed struct-of-arrays layout.

    ``L[b, 0, h]``/``L[b, 1, h]`` are the constraint normal components,
    ``L[b, 2, h]`` the offset, ``L[b, 3, h]`` zero padding (keeps the
    sublane count a power of two).  Columns ``h >= m_valid[b, 0]`` are
    the neutral constraint ``0*x <= 1``.
    """

    L: jax.Array        # (B, 4, m_pad) packed (a_x, a_y, b, 0)
    c: jax.Array        # (B, 2) objective directions (maximize)
    m_valid: jax.Array  # (B, 1) int32 valid (non-padding) rows

    @property
    def batch(self) -> int:
        return self.L.shape[0]

    @property
    def m_pad(self) -> int:
        return self.L.shape[2]

    # Row views (no copies: slices of L).
    @property
    def ax(self) -> jax.Array:
        return self.L[:, 0, :]

    @property
    def ay(self) -> jax.Array:
        return self.L[:, 1, :]

    @property
    def b(self) -> jax.Array:
        return self.L[:, 2, :]

    def unpack(self) -> LPBatch:
        return unpack(self)


def pack(batch: LPBatch, m_pad: int | None = None) -> PackedLPBatch:
    """AoS -> SoA: the one conversion point (counted).

    ``m_pad`` pads the constraint axis with neutral rows; the default
    (``m``) makes ``unpack(pack(batch))`` exactly lossless.  Layout
    consumers with alignment needs (the Pallas kernel wants 128-lane
    multiples) pad further via :func:`pad_packed`.
    """
    global _PACK_CALLS
    _PACK_CALLS += 1
    B, m = batch.batch, batch.m
    if m_pad is None:
        m_pad = m
    if m_pad < m:
        raise ValueError(f"m_pad={m_pad} < m={m}")
    dt = batch.A.dtype
    ax = batch.A[..., 0]
    ay = batch.A[..., 1]
    bb = batch.b
    zeros = jnp.zeros_like(ax)
    L = jnp.stack([ax, ay, bb, zeros], axis=1)  # (B, 4, m)
    pb = PackedLPBatch(L=L, c=batch.c.astype(dt),
                       m_valid=batch.m_valid.reshape(B, 1))
    return pad_packed(pb, m_pad)


def unpack(pb: PackedLPBatch) -> LPBatch:
    """SoA -> AoS (padding columns kept as neutral rows)."""
    A = jnp.stack([pb.L[:, 0, :], pb.L[:, 1, :]], axis=-1)  # (B, m_pad, 2)
    return LPBatch(A=A, b=pb.L[:, 2, :], c=pb.c,
                   m_valid=pb.m_valid.reshape(-1).astype(jnp.int32))


def pad_packed(pb: PackedLPBatch, m_pad: int) -> PackedLPBatch:
    """Pad the constraint axis up to ``m_pad`` with neutral columns
    (a = 0, b = 1) — the packed twin of ``lp.pad_batch``."""
    m = pb.m_pad
    if m_pad < m:
        raise ValueError(f"m_pad={m_pad} < m_pad={m}")
    if m_pad == m:
        return pb
    L = jnp.pad(pb.L, ((0, 0), (0, 0), (0, m_pad - m)))
    L = L.at[:, 2, m:].set(jnp.asarray(PAD_B, L.dtype))
    return PackedLPBatch(L=L, c=pb.c, m_valid=pb.m_valid)


def pad_packed_batch_dim(pb: PackedLPBatch, b_pad: int) -> PackedLPBatch:
    """Pad the batch axis up to ``b_pad`` with neutral problems
    (m_valid=0, c=(1,0)) — the packed twin of ``lp.pad_batch_dim``."""
    B = pb.batch
    if b_pad < B:
        raise ValueError(f"b_pad={b_pad} < batch={B}")
    if b_pad == B:
        return pb
    pad = b_pad - B
    L = jnp.pad(pb.L, ((0, pad), (0, 0), (0, 0)))
    L = L.at[B:, 2, :].set(jnp.asarray(PAD_B, L.dtype))
    c = jnp.concatenate(
        [pb.c, jnp.broadcast_to(jnp.asarray([1.0, 0.0], pb.c.dtype),
                                (pad, 2))])
    mv = jnp.concatenate(
        [pb.m_valid, jnp.zeros((pad, 1), pb.m_valid.dtype)])
    return PackedLPBatch(L=L, c=c, m_valid=mv)


def concat_packed(pbs: list[PackedLPBatch]) -> PackedLPBatch:
    """Fuse packed batches along the batch axis (members padded with
    neutral columns to the largest ``m_pad``) — twin of
    ``lp.concat_batches``."""
    if not pbs:
        raise ValueError("concat_packed of empty list")
    m_max = max(pb.m_pad for pb in pbs)
    padded = [pad_packed(pb, m_max) for pb in pbs]
    return PackedLPBatch(
        L=jnp.concatenate([pb.L for pb in padded]),
        c=jnp.concatenate([pb.c for pb in padded]),
        m_valid=jnp.concatenate([pb.m_valid for pb in padded]),
    )


def split_packed(pb: PackedLPBatch, sizes: list[int],
                 *, allow_remainder: bool = False) -> list[PackedLPBatch]:
    """Inverse of :func:`concat_packed` — twin of ``lp.split_batch``
    (same remainder policy)."""
    total = sum(sizes)
    if total > pb.batch:
        raise ValueError(f"split sizes {sizes} exceed batch {pb.batch}")
    if total < pb.batch and not allow_remainder:
        raise ValueError(
            f"split sizes {sizes} sum to {total} < batch {pb.batch}; "
            "pass allow_remainder=True to drop the trailing problems")
    out, lo = [], 0
    for s in sizes:
        out.append(PackedLPBatch(L=pb.L[lo:lo + s], c=pb.c[lo:lo + s],
                                 m_valid=pb.m_valid[lo:lo + s]))
        lo += s
    return out


def normalize_packed(pb: PackedLPBatch, eps: float = 1e-30
                     ) -> PackedLPBatch:
    """Scale every constraint column so ||a_h|| = 1 — the packed twin of
    ``lp.normalize_batch``, computing the identical scalar pipeline so
    packed and AoS solves stay bit-identical.  Zero-norm (padding)
    columns keep scale 1; the zero sublane rides along (0 * s = 0)."""
    n = _row_norms(pb.ax, pb.ay)  # (B, m_pad)
    is_pad = n < eps
    scale = jnp.where(is_pad, 1.0, 1.0 / jnp.maximum(n, eps))
    return PackedLPBatch(L=pb.L * scale[:, None, :], c=pb.c,
                         m_valid=pb.m_valid)


def shuffle_packed(key: jax.Array, pb: PackedLPBatch) -> PackedLPBatch:
    """Random per-problem constraint order (the R in RGB) — the packed
    twin of ``lp.shuffle_batch``: same score draw, same masking, same
    argsort, so the permutation (and therefore the solve) is
    bit-identical to shuffling the AoS batch when ``m_pad`` matches its
    constraint count.  Padding columns stay at the tail."""
    B, m_pad = pb.batch, pb.m_pad
    scores = jax.random.uniform(key, (B, m_pad))
    idx = jnp.arange(m_pad)[None, :]
    scores = jnp.where(idx < pb.m_valid, scores, jnp.inf)
    order = jnp.argsort(scores, axis=-1)  # (B, m_pad)
    return PackedLPBatch(
        L=jnp.take_along_axis(pb.L, order[:, None, :], axis=2),
        c=pb.c, m_valid=pb.m_valid)
