"""The 1-D linear program at the heart of Seidel's algorithm (paper eqs. 3-4).

When the incremental optimum violates constraint ``l = (a_i, b_i)`` the new
optimum lies on the line ``a_i @ x = b_i``.  Parameterise the line as
``x(t) = p0 + t * u`` with ``p0`` the closest point to the origin and ``u``
the unit direction along the line.  Every previously-considered constraint
``h`` intersects the line at sigma(h, l) = (b_h - a_h @ p0) / (a_h @ u) and
bounds t from the left (a_h @ u < 0) or the right (a_h @ u > 0):

    u_left  = max over left-bounding  sigma(h, l)     (paper eq. 3)
    u_right = min over right-bounding sigma(h, l)     (paper eq. 4)

infeasible iff u_left > u_right, otherwise t* is whichever end the objective
prefers.  These max/min folds are exactly the accumulations the paper
implements with shared-memory atomicMin/atomicMax; on TPU they are lane
reductions (``jnp.min``/``jnp.max``), which are contention-free.

Everything here is written over an arbitrary leading "work-unit" axis so the
same function serves the scalar reference, the hand-vectorised RGB solver and
the Pallas kernel body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# All epsilons are absolute distances because constraints are normalised to
# unit normals before solving (see lp.normalize_batch).
EPS_DENOM = 1e-7   # |a_h @ u| below this -> constraint parallel to the line
EPS_FEAS = 1e-5    # feasibility slack (paper uses a 5-significant-figure
                   # tolerance when comparing CPU and GPU accumulations)
EPS_TIE = 1e-9     # |c @ u| below this -> objective tie, use perpendicular


# The 1-D solve operates on constraint *component rows* (a_x, a_y, b)
# — the packed SoA layout, and the same component arithmetic the
# Pallas kernel body runs.  The dense solvers consume a PackedLPBatch
# directly (no AoS round-trip inside the trace); the AoS entry points
# slice their (…, m, 2) normals into rows and run the *identical*
# graph, which is what makes packed-vs-AoS solves bit-identical by
# construction.
#
# Shape convention: per-problem scalars (a_ix, b_i, cx, …) carry the
# leading batch shape (…,); constraint rows carry one extra trailing
# axis (…, H).  Broadcasting against rows happens via […, None] inside
# these helpers.

def sigma_bounds_rows(ax_prev, ay_prev, b_prev, p0x, p0y, ux, uy, mask):
    """Intersections of previous constraints with the line (the work
    units): all rows (..., H), line frame components pre-expanded to
    (..., 1).  Returns (t_lo, t_hi, parallel_infeasible) reduced over
    H."""
    denom = ax_prev * ux + ay_prev * uy
    num = b_prev - (ax_prev * p0x + ay_prev * p0y)
    is_par = jnp.abs(denom) <= EPS_DENOM
    t = num / jnp.where(is_par, 1.0, denom)  # guarded divide
    big = jnp.asarray(jnp.finfo(t.dtype).max, t.dtype)
    hi = jnp.where(mask & (denom > EPS_DENOM), t, big)       # t <= sigma
    lo = jnp.where(mask & (denom < -EPS_DENOM), t, -big)     # t >= sigma
    t_hi = jnp.min(hi, axis=-1)   # paper eq. 4 (atomicMin on the GPU)
    t_lo = jnp.max(lo, axis=-1)   # paper eq. 3 (atomicMax on the GPU)
    par_bad = jnp.any(mask & is_par & (num < -EPS_FEAS), axis=-1)
    return t_lo, t_hi, par_bad


def choose_t_rows(t_lo, t_hi, cx, cy, cpx, cpy, ux, uy):
    """Pick the end of the feasible interval the (augmented) objective
    prefers.  Ties on c@u are broken with the perpendicular objective
    so the incremental optimum stays unique (required by Seidel's
    algorithm).  The one copy of the tie-break — the dense and chunked
    re-solves must share it bit-for-bit."""
    cu = cx * ux + cy * uy
    cpu = cpx * ux + cpy * uy
    pick_hi = jnp.where(jnp.abs(cu) > EPS_TIE, cu > 0.0, cpu > 0.0)
    return jnp.where(pick_hi, t_hi, t_lo)


def resolve_on_line_rows(a_ix, a_iy, b_i, ax_prev, ay_prev, b_prev,
                         cx, cy, cpx, cpy, mask):
    """The full 1-D re-solve on the line of violated constraint
    ``(a_ix, a_iy, b_i)`` against prior constraint rows.  Returns
    (x_new_x, x_new_y, feasible), each with the leading batch shape."""
    p0x, p0y = a_ix * b_i, a_iy * b_i    # closest point to the origin
    ux, uy = -a_iy, a_ix                 # unit direction along the line
    t_lo, t_hi, par_bad = sigma_bounds_rows(
        ax_prev, ay_prev, b_prev, p0x[..., None], p0y[..., None],
        ux[..., None], uy[..., None], mask)
    feasible = (t_lo <= t_hi + EPS_FEAS) & ~par_bad
    t = choose_t_rows(t_lo, t_hi, cx, cy, cpx, cpy, ux, uy)
    return p0x + t * ux, p0y + t * uy, feasible


def box_rows(M, dtype=jnp.float32):
    """The four bounds x<=M, -x<=M, y<=M, -y<=M that make every
    intermediate optimum finite and unique (paper section 2.1), as
    component rows (bax, bay, bb)."""
    bax = jnp.asarray([1.0, -1.0, 0.0, 0.0], dtype)
    bay = jnp.asarray([0.0, 0.0, 1.0, -1.0], dtype)
    bb = jnp.full((4,), M, dtype)
    return bax, bay, bb


def perp(c):
    return jnp.stack([-c[..., 1], c[..., 0]], axis=-1)


def box_corner(c, M, dtype=None):
    """Initial optimum: the corner of the bounding box |x|,|y| <= M that the
    augmented objective (c, tie-broken by perp(c)) prefers."""
    cp = perp(c)

    def pick(v, tb):
        s = jnp.where(jnp.abs(v) > EPS_TIE, jnp.sign(v),
                      jnp.where(jnp.abs(tb) > EPS_TIE, jnp.sign(tb), 1.0))
        return s

    sx = pick(c[..., 0], cp[..., 0])
    sy = pick(c[..., 1], cp[..., 1])
    x0 = jnp.stack([sx * M, sy * M], axis=-1)
    if dtype is not None:
        x0 = x0.astype(dtype)
    return x0


def box_constraints(M, dtype=jnp.float32):
    """The four bounds x<=M, -x<=M, y<=M, -y<=M that make every intermediate
    optimum finite and unique (paper section 2.1)."""
    A = jnp.asarray(
        [[1.0, 0.0], [-1.0, 0.0], [0.0, 1.0], [0.0, -1.0]], dtype)
    b = jnp.full((4,), M, dtype)
    return A, b
