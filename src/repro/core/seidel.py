"""Batched Seidel incremental 2-D LP solvers (the paper's NaiveRGB and RGB).

Two pure-JAX implementations with deliberately different execution shapes:

``solve_naive`` — NaiveRGB analogue (paper Fig. 1).  One LP per vmap lane.
    Under ``vmap`` the per-LP ``lax.cond`` "do I need a re-solve?" becomes a
    ``select``: *every* lane executes the O(i) re-solve at *every* step,
    exactly like a diverged warp in which one violated thread stalls the
    other 31.  This is the faithful divergence baseline.

``solve_rgb`` — RGB analogue (paper Fig. 2).  The batch is processed in
    tiles (lax.scan over tiles -> real sequential control flow, not vmap).
    Within a tile the step-i membership test is a dense vector op over
    problems, and the O(i) re-solve work units (one per prior constraint)
    are laid along the minor axis and executed as dense vector ops with a
    min/max reduction in place of the paper's shared-memory atomics.  A
    scalar-predicate ``lax.cond`` skips the re-solve entirely whenever *no*
    problem in the tile is violated at step i — the TPU analogue of the
    cooperative-thread-array early exit, and the reason randomised order
    pays off (violations become rare as i grows).

The Pallas TPU kernel (kernels/batch_lp.py) implements the same algorithm as
``solve_rgb`` with explicit VMEM tiling; this module is its oracle.

Both solvers consume constraints as *component rows* ``(a_x, a_y, b)``
— the packed SoA layout — via the ``oneD.*_rows`` helpers (the same
component arithmetic the Pallas kernel body runs).  A
:class:`~repro.core.packed.PackedLPBatch` therefore feeds
``solve_naive_packed``/``solve_rgb_packed`` directly, with no AoS
round-trip inside the trace; the AoS entry points slice their
``(…, m, 2)`` normals into rows and run the identical graph, so packed
and AoS solves are bit-identical by construction.
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core import oneD
from repro.core.lp import LPBatch, LPSolution

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.packed import PackedLPBatch

DEFAULT_M = 1.0e4  # box bound; "very large so as not to affect the optimum"


# ---------------------------------------------------------------------------
# NaiveRGB: vmap of the scalar incremental algorithm
# ---------------------------------------------------------------------------

def _solve_one_rows(ax, ay, bb, c, m_valid, *, M):
    """Scalar Seidel solve for one LP over constraint rows.
    ax/ay/bb (m,), c (2,)."""
    m = ax.shape[0]
    dt = ax.dtype
    bax, bay, bbb = oneD.box_rows(M, dt)
    ax_all = jnp.concatenate([bax, ax])  # (m+4,)
    ay_all = jnp.concatenate([bay, ay])
    b_all = jnp.concatenate([bbb, bb])
    cperp = oneD.perp(c)
    x0 = oneD.box_corner(c, jnp.asarray(M, dt))
    h_idx = jnp.arange(m + 4)

    def body(i, carry):
        x, feas = carry
        a_ix, a_iy, b_i = ax[i], ay[i], bb[i]
        violated = feas & (i < m_valid) & (
            a_ix * x[0] + a_iy * x[1] > b_i + oneD.EPS_FEAS)
        # Under vmap this re-solve is executed by every lane every step
        # (cond -> select): the divergence cost the paper's Fig. 1 shows.
        mask = h_idx < (i + 4)
        xn_x, xn_y, feas_new = oneD.resolve_on_line_rows(
            a_ix, a_iy, b_i, ax_all, ay_all, b_all,
            c[0], c[1], cperp[0], cperp[1], mask)
        x = jnp.where(violated, jnp.stack([xn_x, xn_y]), x)
        feas = jnp.where(violated, feas & feas_new, feas)
        return x, feas

    x, feas = jax.lax.fori_loop(0, m, body, (x0, jnp.asarray(True)))
    return x, feas


def _naive_from_rows(ax, ay, bb, c, m_valid, *, M) -> LPSolution:
    x, feas = jax.vmap(
        functools.partial(_solve_one_rows, M=M)
    )(ax, ay, bb, c, m_valid)
    return LPSolution(x=x, feasible=feas,
                      objective=jnp.einsum("bd,bd->b", c, x))


def solve_naive(batch: LPBatch, *, M: float = DEFAULT_M) -> LPSolution:
    return _naive_from_rows(batch.A[..., 0], batch.A[..., 1], batch.b,
                            batch.c, batch.m_valid, M=M)


def solve_naive_packed(pb: "PackedLPBatch", *,
                       M: float = DEFAULT_M) -> LPSolution:
    """The packed fast path: consume ``PackedLPBatch.L`` rows directly
    (no AoS round-trip inside the trace)."""
    return _naive_from_rows(pb.ax, pb.ay, pb.b, pb.c,
                            pb.m_valid.reshape(-1), M=M)


# ---------------------------------------------------------------------------
# RGB: tile-cooperative work-unit execution
# ---------------------------------------------------------------------------

def _solve_tile_rows(ax, ay, bb, c, m_valid, *, M, chunk: int = 0):
    """Solve a tile of T problems cooperatively over constraint rows.

    ax/ay/bb (T, m), c (T, 2), m_valid (T,).

    chunk > 0 enables the *chunked re-solve* (beyond-paper optimisation,
    EXPERIMENTS.md section Perf-LP): the 1-D LP at step i only touches the
    first ceil((i+4)/chunk) lane-chunks of prior constraints, so re-solve
    work is O(i) like the serial algorithm, instead of O(m) dense.  The
    paper's WU count is i per re-solve; the dense variant pays m.
    """
    T, m = ax.shape
    dt = ax.dtype
    bax, bay, bbb = oneD.box_rows(M, dt)
    ax_all = jnp.concatenate(
        [jnp.broadcast_to(bax, (T, 4)), ax], axis=1)  # (T, H)
    ay_all = jnp.concatenate([jnp.broadcast_to(bay, (T, 4)), ay], axis=1)
    b_all = jnp.concatenate([jnp.broadcast_to(bbb, (T, 4)), bb], axis=1)
    if chunk:
        pad = (-ax_all.shape[1]) % chunk
        ax_all = jnp.pad(ax_all, ((0, 0), (0, pad)))
        ay_all = jnp.pad(ay_all, ((0, 0), (0, pad)))
        b_all = jnp.pad(b_all, ((0, 0), (0, pad)), constant_values=1.0)
    H = ax_all.shape[1]
    cx, cy = c[:, 0], c[:, 1]
    cperp = oneD.perp(c)
    cpx, cpy = cperp[:, 0], cperp[:, 1]
    x0 = oneD.box_corner(c, jnp.asarray(M, dt))
    h_idx = jnp.arange(H)[None, :]  # (1, H)

    def step(i, carry):
        x, feas = carry
        a_ix = jax.lax.dynamic_index_in_dim(ax, i, axis=1, keepdims=False)
        a_iy = jax.lax.dynamic_index_in_dim(ay, i, axis=1, keepdims=False)
        b_i = jax.lax.dynamic_index_in_dim(bb, i, axis=1, keepdims=False)
        violated = feas & (i < m_valid) & (
            a_ix * x[:, 0] + a_iy * x[:, 1] > b_i + oneD.EPS_FEAS)

        def resolve(xf):
            x, feas = xf
            # Work units: all (problem, prior-constraint) intersections,
            # laid dense along the minor axis; masked min/max reduction
            # replaces shared-memory atomics.
            if not chunk:
                mask = h_idx < (i + 4)
                xn_x, xn_y, feas_new = oneD.resolve_on_line_rows(
                    a_ix, a_iy, b_i, ax_all, ay_all, b_all,
                    cx, cy, cpx, cpy, mask)
            else:
                xn_x, xn_y, feas_new = _resolve_chunked_rows(
                    a_ix, a_iy, b_i, ax_all, ay_all, b_all,
                    cx, cy, cpx, cpy, i + 4, chunk)
            x_new = jnp.stack([xn_x, xn_y], axis=-1)
            x = jnp.where(violated[:, None], x_new, x)
            feas = jnp.where(violated, feas & feas_new, feas)
            return x, feas

        # Scalar predicate -> genuine skip (block-level early exit).
        return jax.lax.cond(jnp.any(violated), resolve, lambda xf: xf,
                            (x, feas))

    x, feas = jax.lax.fori_loop(0, m, step, (x0, jnp.ones((T,), bool)))
    return x, feas


def _resolve_chunked_rows(a_ix, a_iy, b_i, ax_all, ay_all, b_all,
                          cx, cy, cpx, cpy, n_prior, chunk):
    """1-D re-solve touching only ceil(n_prior/chunk) lane-chunks."""
    T, H = ax_all.shape
    dt = ax_all.dtype
    p0x, p0y = a_ix * b_i, a_iy * b_i
    ux, uy = -a_iy, a_ix
    big = jnp.asarray(jnp.finfo(dt).max, dt)
    n_chunks = (n_prior + chunk - 1) // chunk

    def body(j, carry):
        t_lo, t_hi, bad = carry
        axc = jax.lax.dynamic_slice_in_dim(ax_all, j * chunk, chunk,
                                           axis=1)
        ayc = jax.lax.dynamic_slice_in_dim(ay_all, j * chunk, chunk,
                                           axis=1)
        bsc = jax.lax.dynamic_slice_in_dim(b_all, j * chunk, chunk,
                                           axis=1)
        hloc = j * chunk + jnp.arange(chunk)[None, :]
        mask = hloc < n_prior
        lo_j, hi_j, bad_j = oneD.sigma_bounds_rows(
            axc, ayc, bsc, p0x[..., None], p0y[..., None],
            ux[..., None], uy[..., None], mask)
        return (jnp.maximum(t_lo, lo_j), jnp.minimum(t_hi, hi_j),
                bad | bad_j)

    t_lo0 = jnp.full((T,), -big)
    t_hi0 = jnp.full((T,), big)
    bad0 = jnp.zeros((T,), bool)
    t_lo, t_hi, bad = jax.lax.fori_loop(0, n_chunks, body,
                                        (t_lo0, t_hi0, bad0))
    feasible = (t_lo <= t_hi + oneD.EPS_FEAS) & ~bad
    t = oneD.choose_t_rows(t_lo, t_hi, cx, cy, cpx, cpy, ux, uy)
    return p0x + t * ux, p0y + t * uy, feasible


def _rgb_from_rows(ax, ay, bb, c, m_valid, *, M, tile, chunk) -> LPSolution:
    B, m = ax.shape
    T = min(tile, B) if B > 0 else tile
    n_tiles = -(-B // T)
    pad = n_tiles * T - B

    def padded(a, fill):
        if pad == 0:
            return a
        width = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, width, constant_values=fill)

    axs = padded(ax, 0.0).reshape(n_tiles, T, m)
    ays = padded(ay, 0.0).reshape(n_tiles, T, m)
    bs = padded(bb, 1.0).reshape(n_tiles, T, m)
    cs = padded(c, 1.0).reshape(n_tiles, T, 2)
    mv = padded(m_valid, 0).reshape(n_tiles, T)

    def scan_body(_, xs):
        axi, ayi, bi, ci, mvi = xs
        x, feas = _solve_tile_rows(axi, ayi, bi, ci, mvi, M=M,
                                   chunk=chunk)
        return None, (x, feas)

    _, (x, feas) = jax.lax.scan(scan_body, None, (axs, ays, bs, cs, mv))
    x = x.reshape(n_tiles * T, 2)[:B]
    feas = feas.reshape(n_tiles * T)[:B]
    return LPSolution(x=x, feasible=feas,
                      objective=jnp.einsum("bd,bd->b", c, x))


def solve_rgb(batch: LPBatch, *, M: float = DEFAULT_M,
              tile: int = 32, chunk: int = 0) -> LPSolution:
    return _rgb_from_rows(batch.A[..., 0], batch.A[..., 1], batch.b,
                          batch.c, batch.m_valid, M=M, tile=tile,
                          chunk=chunk)


def solve_rgb_packed(pb: "PackedLPBatch", *, M: float = DEFAULT_M,
                     tile: int = 32, chunk: int = 0) -> LPSolution:
    """The packed fast path: consume ``PackedLPBatch.L`` rows directly
    (no AoS round-trip inside the trace)."""
    return _rgb_from_rows(pb.ax, pb.ay, pb.b, pb.c,
                          pb.m_valid.reshape(-1), M=M, tile=tile,
                          chunk=chunk)