"""Batched Seidel incremental 2-D LP solvers (the paper's NaiveRGB and RGB).

Two pure-JAX implementations with deliberately different execution shapes:

``solve_naive`` — NaiveRGB analogue (paper Fig. 1).  One LP per vmap lane.
    Under ``vmap`` the per-LP ``lax.cond`` "do I need a re-solve?" becomes a
    ``select``: *every* lane executes the O(i) re-solve at *every* step,
    exactly like a diverged warp in which one violated thread stalls the
    other 31.  This is the faithful divergence baseline.

``solve_rgb`` — RGB analogue (paper Fig. 2).  The batch is processed in
    tiles (lax.scan over tiles -> real sequential control flow, not vmap).
    Within a tile the step-i membership test is a dense vector op over
    problems, and the O(i) re-solve work units (one per prior constraint)
    are laid along the minor axis and executed as dense vector ops with a
    min/max reduction in place of the paper's shared-memory atomics.  A
    scalar-predicate ``lax.cond`` skips the re-solve entirely whenever *no*
    problem in the tile is violated at step i — the TPU analogue of the
    cooperative-thread-array early exit, and the reason randomised order
    pays off (violations become rare as i grows).

The Pallas TPU kernel (kernels/batch_lp.py) implements the same algorithm as
``solve_rgb`` with explicit VMEM tiling; this module is its oracle.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import oneD
from repro.core.lp import LPBatch, LPSolution

DEFAULT_M = 1.0e4  # box bound; "very large so as not to affect the optimum"


# ---------------------------------------------------------------------------
# NaiveRGB: vmap of the scalar incremental algorithm
# ---------------------------------------------------------------------------

def _solve_one(A, b, c, m_valid, *, M):
    """Scalar Seidel solve for one LP.  A (m,2), b (m,), c (2,)."""
    m = A.shape[0]
    dt = A.dtype
    boxA, boxb = oneD.box_constraints(M, dt)
    Aall = jnp.concatenate([boxA, A], axis=0)  # (m+4, 2)
    ball = jnp.concatenate([boxb, b], axis=0)
    cperp = oneD.perp(c)
    x0 = oneD.box_corner(c, jnp.asarray(M, dt))
    h_idx = jnp.arange(m + 4)

    def body(i, carry):
        x, feas = carry
        a_i, b_i = A[i], b[i]
        violated = feas & (i < m_valid) & (
            jnp.dot(a_i, x) > b_i + oneD.EPS_FEAS)
        # Under vmap this re-solve is executed by every lane every step
        # (cond -> select): the divergence cost the paper's Fig. 1 shows.
        mask = h_idx < (i + 4)
        x_new, feas_new = oneD.resolve_on_line(
            a_i, b_i, Aall, ball, c, cperp, mask)
        x = jnp.where(violated, x_new, x)
        feas = jnp.where(violated, feas & feas_new, feas)
        return x, feas

    x, feas = jax.lax.fori_loop(0, m, body, (x0, jnp.asarray(True)))
    return x, feas


def solve_naive(batch: LPBatch, *, M: float = DEFAULT_M) -> LPSolution:
    x, feas = jax.vmap(
        functools.partial(_solve_one, M=M)
    )(batch.A, batch.b, batch.c, batch.m_valid)
    return LPSolution(x=x, feasible=feas,
                      objective=jnp.einsum("bd,bd->b", batch.c, x))


# ---------------------------------------------------------------------------
# RGB: tile-cooperative work-unit execution
# ---------------------------------------------------------------------------

def _solve_tile(A, b, c, m_valid, *, M, chunk: int = 0):
    """Solve a tile of T problems cooperatively.

    A (T, m, 2), b (T, m), c (T, 2), m_valid (T,).

    chunk > 0 enables the *chunked re-solve* (beyond-paper optimisation,
    EXPERIMENTS.md section Perf-LP): the 1-D LP at step i only touches the
    first ceil((i+4)/chunk) lane-chunks of prior constraints, so re-solve
    work is O(i) like the serial algorithm, instead of O(m) dense.  The
    paper's WU count is i per re-solve; the dense variant pays m.
    """
    T, m = A.shape[0], A.shape[1]
    dt = A.dtype
    boxA, boxb = oneD.box_constraints(M, dt)
    Aall = jnp.concatenate([jnp.broadcast_to(boxA, (T, 4, 2)), A], axis=1)
    ball = jnp.concatenate([jnp.broadcast_to(boxb, (T, 4)), b], axis=1)
    if chunk:
        pad = (-Aall.shape[1]) % chunk
        Aall = jnp.pad(Aall, ((0, 0), (0, pad), (0, 0)))
        ball = jnp.pad(ball, ((0, 0), (0, pad)), constant_values=1.0)
    H = Aall.shape[1]
    cperp = oneD.perp(c)
    x0 = oneD.box_corner(c, jnp.asarray(M, dt))
    h_idx = jnp.arange(H)[None, :]  # (1, H)

    def step(i, carry):
        x, feas = carry
        a_i = jax.lax.dynamic_index_in_dim(A, i, axis=1, keepdims=False)
        b_i = jax.lax.dynamic_index_in_dim(b, i, axis=1, keepdims=False)
        violated = feas & (i < m_valid) & (
            jnp.einsum("td,td->t", a_i, x) > b_i + oneD.EPS_FEAS)

        def resolve(xf):
            x, feas = xf
            # Work units: all (problem, prior-constraint) intersections,
            # laid dense along the minor axis; masked min/max reduction
            # replaces shared-memory atomics.
            if not chunk:
                mask = h_idx < (i + 4)
                x_new, feas_new = oneD.resolve_on_line(
                    a_i, b_i, Aall, ball, c, cperp, mask)
            else:
                x_new, feas_new = _resolve_chunked(
                    a_i, b_i, Aall, ball, c, cperp, i + 4, chunk)
            x = jnp.where(violated[:, None], x_new, x)
            feas = jnp.where(violated, feas & feas_new, feas)
            return x, feas

        # Scalar predicate -> genuine skip (block-level early exit).
        return jax.lax.cond(jnp.any(violated), resolve, lambda xf: xf,
                            (x, feas))

    x, feas = jax.lax.fori_loop(0, m, step, (x0, jnp.ones((T,), bool)))
    return x, feas


def _resolve_chunked(a_i, b_i, Aall, ball, c, cperp, n_prior, chunk):
    """1-D re-solve touching only ceil(n_prior/chunk) lane-chunks."""
    T, H, _ = Aall.shape
    dt = Aall.dtype
    p0, u = oneD.line_frame(a_i, b_i)
    big = jnp.asarray(jnp.finfo(dt).max, dt)
    n_chunks = (n_prior + chunk - 1) // chunk

    def body(j, carry):
        t_lo, t_hi, bad = carry
        As = jax.lax.dynamic_slice_in_dim(Aall, j * chunk, chunk, axis=1)
        bs = jax.lax.dynamic_slice_in_dim(ball, j * chunk, chunk, axis=1)
        hloc = j * chunk + jnp.arange(chunk)[None, :]
        mask = hloc < n_prior
        lo_j, hi_j, bad_j = oneD.sigma_bounds(As, bs, p0, u, mask)
        return (jnp.maximum(t_lo, lo_j), jnp.minimum(t_hi, hi_j),
                bad | bad_j)

    t_lo0 = jnp.full((T,), -big)
    t_hi0 = jnp.full((T,), big)
    bad0 = jnp.zeros((T,), bool)
    t_lo, t_hi, bad = jax.lax.fori_loop(0, n_chunks, body,
                                        (t_lo0, t_hi0, bad0))
    feasible = (t_lo <= t_hi + oneD.EPS_FEAS) & ~bad
    t = oneD.choose_t(t_lo, t_hi, c, cperp, u)
    return p0 + t[..., None] * u, feasible


def solve_rgb(batch: LPBatch, *, M: float = DEFAULT_M,
              tile: int = 32, chunk: int = 0) -> LPSolution:
    B, m = batch.batch, batch.m
    T = min(tile, B) if B > 0 else tile
    n_tiles = -(-B // T)
    pad = n_tiles * T - B

    def padded(a, fill):
        if pad == 0:
            return a
        width = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, width, constant_values=fill)

    A = padded(batch.A, 0.0).reshape(n_tiles, T, m, 2)
    b = padded(batch.b, 1.0).reshape(n_tiles, T, m)
    c = padded(batch.c, 1.0).reshape(n_tiles, T, 2)
    mv = padded(batch.m_valid, 0).reshape(n_tiles, T)

    def scan_body(_, xs):
        Ai, bi, ci, mvi = xs
        x, feas = _solve_tile(Ai, bi, ci, mvi, M=M, chunk=chunk)
        return None, (x, feas)

    _, (x, feas) = jax.lax.scan(scan_body, None, (A, b, c, mv))
    x = x.reshape(n_tiles * T, 2)[:B]
    feas = feas.reshape(n_tiles * T)[:B]
    return LPSolution(x=x, feasible=feas,
                      objective=jnp.einsum("bd,bd->b", batch.c, x))


# ---------------------------------------------------------------------------
# Deprecated public entry point (shim over repro.solver)
# ---------------------------------------------------------------------------

_DEPRECATION_WARNED = False


def solve_batch_lp(
    batch: LPBatch,
    *,
    method: str = "rgb",
    key: Optional[jax.Array] = None,
    M: float = DEFAULT_M,
    tile: int = 32,
    chunk: int = 0,
    normalize: bool = True,
    interpret: Optional[bool] = None,
) -> LPSolution:
    """Deprecated: build a :class:`repro.solver.SolverSpec` instead.

    This shim maps the historical ``method=`` kwargs onto an equivalent
    spec and delegates to its process-cached
    :class:`~repro.solver.solver.Solver`, so results are identical to
    ``SolverSpec(...).build().solve(batch, key=key)``.  One
    DeprecationWarning is emitted per process.  Quirk preserved for
    compatibility: ``method="kernel"`` ignores ``tile``/``chunk`` (the
    kernel picks a VMEM-budgeted tile), exactly as before.
    """
    global _DEPRECATION_WARNED
    if not _DEPRECATION_WARNED:
        _DEPRECATION_WARNED = True
        warnings.warn(
            "core.solve_batch_lp(method=...) is deprecated; use "
            "repro.solver.SolverSpec(backend=...).build() and call "
            ".solve(batch) on the result", DeprecationWarning,
            stacklevel=2)
    from repro.solver import SolverSpec, get_solver  # lazy: import cycle
    if method == "kernel":
        spec = SolverSpec(backend="kernel", M=M, normalize=normalize,
                          interpret=interpret)
    else:
        spec = SolverSpec(backend=method, tile=tile, chunk=chunk, M=M,
                          normalize=normalize, interpret=interpret)
    return get_solver(spec).solve(batch, key=key)
