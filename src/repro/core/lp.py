"""Problem types, generators and batch utilities for 2-D linear programs.

A single LP is   maximize  c @ x   subject to  A @ x <= b,  x in R^2.

Batches are stored dense:  A (B, m, 2), b (B, m), c (B, 2).  Ragged batches
(the paper's "different-sized individual LPs within the batches") carry a
per-problem valid count ``m_valid`` and pad the tail with the *neutral
constraint* ``0*x + 0*y <= 1`` which is satisfied by every point and ignored
by the 1-D re-solve (its normal has zero norm).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Neutral padding constraint: 0*x <= 1 (always satisfied, zero normal).
PAD_A = (0.0, 0.0)
PAD_B = 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LPBatch:
    """A batch of 2-D linear programs (dense layout, optionally ragged)."""

    A: jax.Array  # (B, m, 2) constraint normals
    b: jax.Array  # (B, m)    constraint offsets
    c: jax.Array  # (B, 2)    objective directions (maximize)
    m_valid: jax.Array  # (B,) int32 number of valid (non-padding) rows

    @property
    def batch(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    def pack(self, m_pad: int | None = None):
        """AoS -> packed SoA (:class:`~repro.core.packed.PackedLPBatch`).
        Pack once before repeated solves; see ``repro.core.packed``."""
        from repro.core.packed import pack  # deferred: import cycle
        return pack(self, m_pad)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LPSolution:
    x: jax.Array  # (B, 2) argmax (garbage where infeasible)
    feasible: jax.Array  # (B,) bool
    objective: jax.Array  # (B,) c @ x (garbage where infeasible)


def make_batch(A, b, c, m_valid=None) -> LPBatch:
    A = jnp.asarray(A)
    if not jnp.issubdtype(A.dtype, jnp.floating):
        A = A.astype(jnp.float32)
    # One dtype for the whole problem: mixed inputs (e.g. a float64 b
    # against a float32 A) used to flow through silently and blow up
    # later in pad/normalize concatenations or solver promotion.
    b = jnp.asarray(b, A.dtype)
    c = jnp.asarray(c, A.dtype)
    if A.ndim == 2:  # single problem -> batch of one
        A, b, c = A[None], b[None], c[None]
    B, m = A.shape[0], A.shape[1]
    if m_valid is None:
        m_valid = jnp.full((B,), m, dtype=jnp.int32)
    else:
        m_valid = jnp.asarray(m_valid, dtype=jnp.int32)
    return LPBatch(A=A, b=b, c=c, m_valid=m_valid)


def pad_batch(batch: LPBatch, m_pad: int) -> LPBatch:
    """Pad the constraint dimension up to ``m_pad`` with neutral rows."""
    B, m = batch.batch, batch.m
    if m_pad < m:
        raise ValueError(f"m_pad={m_pad} < m={m}")
    if m_pad == m:
        return batch
    dt = batch.A.dtype
    padA = jnp.broadcast_to(jnp.asarray(PAD_A, dt), (B, m_pad - m, 2))
    padb = jnp.full((B, m_pad - m), PAD_B, dt)
    return LPBatch(
        A=jnp.concatenate([batch.A, padA], axis=1),
        b=jnp.concatenate([batch.b, padb], axis=1),
        c=batch.c,
        m_valid=batch.m_valid,
    )


def pad_batch_dim(batch: LPBatch, b_pad: int) -> LPBatch:
    """Pad the *batch* dimension up to ``b_pad`` with neutral problems
    (m_valid=0, c=(1,0)): they solve at the box corner in zero iterations
    and never trigger a re-solve."""
    B, m = batch.batch, batch.m
    if b_pad < B:
        raise ValueError(f"b_pad={b_pad} < batch={B}")
    if b_pad == B:
        return batch
    pad = b_pad - B
    dt = batch.A.dtype
    return LPBatch(
        A=jnp.concatenate(
            [batch.A, jnp.broadcast_to(jnp.asarray(PAD_A, dt),
                                       (pad, m, 2))]),
        b=jnp.concatenate([batch.b, jnp.full((pad, m), PAD_B, dt)]),
        c=jnp.concatenate(
            [batch.c, jnp.broadcast_to(jnp.asarray([1.0, 0.0], dt),
                                       (pad, 2))]),
        m_valid=jnp.concatenate(
            [batch.m_valid, jnp.zeros((pad,), jnp.int32)]),
    )


def concat_batches(batches: list[LPBatch]) -> LPBatch:
    """Fuse several batches into one super-batch: every member is padded
    (neutral rows) to the largest constraint count, then stacked along the
    batch dimension.  For callers fusing pre-built batches offline; the
    serving scheduler assembles the same layout host-side in numpy
    (serve_lp.scheduler._solve) to keep flushes off the device."""
    if not batches:
        raise ValueError("concat_batches of empty list")
    m_max = max(b.m for b in batches)
    padded = [pad_batch(b, m_max) for b in batches]
    return LPBatch(
        A=jnp.concatenate([b.A for b in padded]),
        b=jnp.concatenate([b.b for b in padded]),
        c=jnp.concatenate([b.c for b in padded]),
        m_valid=jnp.concatenate([b.m_valid for b in padded]),
    )


def split_batch(batch: LPBatch, sizes: list[int],
                *, allow_remainder: bool = False) -> list[LPBatch]:
    """Inverse of :func:`concat_batches`: slice the batch dimension back
    into consecutive pieces of the given sizes (padding rows kept).

    ``sizes`` must cover the batch exactly — a shortfall used to drop
    the trailing problems silently; now it raises unless
    ``allow_remainder=True`` is passed explicitly (the remainder is then
    discarded, e.g. to strip padding problems off a fused flush)."""
    total = sum(sizes)
    if total > batch.batch:
        raise ValueError(
            f"split sizes {sizes} exceed batch {batch.batch}")
    if total < batch.batch and not allow_remainder:
        raise ValueError(
            f"split sizes {sizes} sum to {total} < batch {batch.batch}; "
            "pass allow_remainder=True to drop the trailing problems")
    out, lo = [], 0
    for s in sizes:
        out.append(LPBatch(A=batch.A[lo:lo + s], b=batch.b[lo:lo + s],
                           c=batch.c[lo:lo + s],
                           m_valid=batch.m_valid[lo:lo + s]))
        lo += s
    return out


def _row_norms(ax: jax.Array, ay: jax.Array) -> jax.Array:
    """||a|| per constraint from its components — the one norm op both
    the AoS and packed normalisers run, so packed/AoS bit-identity holds
    by construction.  Must stay reduce-based (not a hand-fused
    ``sqrt(x*x + y*y)``, which XLA FMA-fuses differently under jit than
    in eager execution)."""
    return jnp.linalg.norm(jnp.stack([ax, ay], axis=-1), axis=-1)


def normalize_batch(batch: LPBatch, eps: float = 1e-30) -> LPBatch:
    """Scale every constraint so ||a_h|| = 1 (zero-norm padding rows kept).

    Normalisation makes every epsilon threshold in the solver an absolute
    distance, which is what keeps float32 behaviour within the paper's own
    5-significant-figure tolerance.
    """
    n = _row_norms(batch.A[..., 0], batch.A[..., 1])[..., None]  # (B, m, 1)
    is_pad = n[..., 0] < eps
    scale = jnp.where(is_pad[..., None], 1.0, 1.0 / jnp.maximum(n, eps))
    return LPBatch(
        A=batch.A * scale,
        b=batch.b * scale[..., 0],
        c=batch.c,
        m_valid=batch.m_valid,
    )


def shuffle_batch(key: jax.Array, batch: LPBatch) -> LPBatch:
    """Random per-problem constraint order — the R in RGB (Seidel's
    randomisation).  Valid rows are permuted uniformly; padding rows stay at
    the tail so ragged masks remain prefix masks."""
    B, m = batch.batch, batch.m
    scores = jax.random.uniform(key, (B, m))
    idx = jnp.arange(m)[None, :]
    scores = jnp.where(idx < batch.m_valid[:, None], scores, jnp.inf)
    order = jnp.argsort(scores, axis=-1)  # (B, m)
    take = jax.vmap(lambda a, o: a[o])
    return LPBatch(
        A=take(batch.A, order), b=take(batch.b, order), c=batch.c,
        m_valid=batch.m_valid,
    )


# ---------------------------------------------------------------------------
# Problem generators (mirroring the paper's experimental setup, section 4)
# ---------------------------------------------------------------------------

def random_feasible_lp(
    key: jax.Array,
    batch: int,
    m: int,
    *,
    dtype=jnp.float32,
    radius: float = 100.0,
    slack: float = 5.0,
) -> LPBatch:
    """Random feasible problems: pick an interior point per problem, draw
    constraint normals uniformly on the circle and offset them so the
    interior point is strictly feasible (paper: "constraint lines are
    generated randomly and tested to ensure a solution is possible")."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xstar = jax.random.uniform(k1, (batch, 1, 2), dtype, -radius / 2, radius / 2)
    theta = jax.random.uniform(k2, (batch, m), dtype, 0.0, 2.0 * np.pi)
    A = jnp.stack([jnp.cos(theta), jnp.sin(theta)], axis=-1)  # (B, m, 2)
    s = jax.random.uniform(k3, (batch, m), dtype, 0.1, slack)
    b = jnp.einsum("bmd,bmd->bm", A, jnp.broadcast_to(xstar, A.shape)) + s
    phi = jax.random.uniform(k4, (batch,), dtype, 0.0, 2.0 * np.pi)
    c = jnp.stack([jnp.cos(phi), jnp.sin(phi)], axis=-1)
    return make_batch(A, b, c)


def replicated_lp(key: jax.Array, batch: int, m: int, **kw) -> LPBatch:
    """Paper's batch construction: one LP generated per run and copied
    ``batch`` times into memory to simulate batch numbers."""
    one = random_feasible_lp(key, 1, m, **kw)
    rep = lambda a: jnp.broadcast_to(a, (batch,) + a.shape[1:])
    return LPBatch(A=rep(one.A), b=rep(one.b), c=rep(one.c),
                   m_valid=rep(one.m_valid))


def adversarial_lp(batch: int, m: int, *, dtype=jnp.float32) -> LPBatch:
    """Worst-case consideration order (paper section 2.1): constraints are
    tangents to the unit circle with angles sweeping monotonically toward
    the objective direction, so *every* constraint, considered in the given
    order, invalidates the previous intermediate optimum.  Used to benchmark
    the naive/RGB divergence gap and the value of randomisation."""
    i = np.arange(m, dtype=np.float64)
    # Angles converge geometrically toward pi/2 (the optimum for c=(0,1)).
    ang = np.pi / 2 + (np.pi / 2.2) * (0.98 ** i) * np.where(i % 2 == 0, 1.0, -1.0)
    A = np.stack([np.cos(ang), np.sin(ang)], axis=-1)
    b = np.ones((m,))
    A = jnp.asarray(np.broadcast_to(A, (batch, m, 2)), dtype)
    b = jnp.asarray(np.broadcast_to(b, (batch, m)), dtype)
    c = jnp.broadcast_to(jnp.asarray([0.0, 1.0], dtype), (batch, 2))
    return make_batch(A, b, c)


def ragged_feasible_lp(
    key: jax.Array, batch: int, m_max: int, *, m_min: int = 4, dtype=jnp.float32
) -> LPBatch:
    """Different-sized LPs in one batch (paper section 6 'allowance for
    different-sized individual LPs within the batches')."""
    kf, km = jax.random.split(key)
    full = random_feasible_lp(kf, batch, m_max, dtype=dtype)
    m_valid = jax.random.randint(km, (batch,), m_min, m_max + 1)
    idx = jnp.arange(m_max)[None, :]
    keep = idx < m_valid[:, None]
    A = jnp.where(keep[..., None], full.A, jnp.asarray(PAD_A, dtype))
    b = jnp.where(keep, full.b, jnp.asarray(PAD_B, dtype))
    return LPBatch(A=A, b=b, c=full.c, m_valid=m_valid.astype(jnp.int32))


def infeasible_lp(batch: int, m: int, *, dtype=jnp.float32) -> LPBatch:
    """x <= -1 and -x <= -1 (i.e. x >= 1): empty feasible set; remaining
    rows neutral."""
    A = np.zeros((m, 2))
    b = np.full((m,), PAD_B)
    A[0] = (1.0, 0.0); b[0] = -1.0
    A[1] = (-1.0, 0.0); b[1] = -1.0
    A = jnp.asarray(np.broadcast_to(A, (batch, m, 2)), dtype)
    b = jnp.asarray(np.broadcast_to(b, (batch, m)), dtype)
    c = jnp.broadcast_to(jnp.asarray([1.0, 0.0], dtype), (batch, 2))
    return make_batch(A, b, c)
