"""Roofline-term derivation from compiled XLA artifacts.

Per the brief (TPU v5e targets):
    compute    = HLO_FLOPs   / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective = coll_bytes  / (chips * 50e9   B/s ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective bytes are parsed out of the optimized HLO text by summing the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # B/s per chip
LINK_BW = 50e9            # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(ty: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(ty):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/#]+?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(([^)]*)\)",
)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/#]+?)\s+"
    r"(convert)\((\w+\[[\d,]*\])")
_OPERAND_RE = re.compile(r"(%?[\w.\-]+)$")


def collective_bytes(hlo_text: str, *,
                     bf16_activations: bool = True) -> Dict[str, int]:
    """Sum result-type bytes per collective op kind.

    ``-done`` ops are skipped (their ``-start`` twin carries the payload).

    bf16_activations: the CPU backend emulates bf16 by running the whole
    program in f32, so every activation / cotangent collective appears at
    twice its TPU wire size.  When the model computes in bf16 we count f32
    collectives >= 1 MiB at half size (the genuinely-f32 collectives in
    our programs are scalar loss/token-count psums, far below 1 MiB)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        ty, op = m.group(1), m.group(2)
        b = _type_bytes(ty)
        if bf16_activations and b >= (1 << 20) and "f32" in ty \
                and "bf16" not in ty:
            b //= 2
        out[op] = out.get(op, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    """All HLO-derived quantities are PER DEVICE: XLA's cost_analysis (and
    the HLO text) describe the SPMD per-device program.  ``model_flops``
    is the global useful-work estimate."""
    flops: float            # HLO flops per device per step
    hbm_bytes: float        # HLO bytes accessed per device (unfused bound)
    coll_bytes: float       # collective operand bytes per device
    chips: int
    model_flops: float      # 6*N*D-style useful flops (global)
    coll_by_op: Dict[str, int] = dataclasses.field(default_factory=dict)
    hbm_fused: float = 0.0  # analytic fused-TPU HBM estimate (preferred)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return (self.hbm_fused or self.hbm_bytes) / HBM_BW

    @property
    def t_memory_unfused(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total compiled flops — catches remat/redundancy."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time over the bound (max term): the score."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / bound if bound else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "hbm_fused_per_dev": self.hbm_fused,
            "coll_bytes_per_dev": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_unfused_s": self.t_memory_unfused,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_by_op": self.coll_by_op,
        }


def from_compiled(compiled, chips: int, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=hbm,
                    coll_bytes=float(sum(coll.values())), chips=chips,
                    model_flops=model_flops, coll_by_op=coll)


def fused_hbm_estimate(cfg, kind: str, batch: int, seq: int,
                       tp: int, data: int) -> float:
    """Analytic per-device HBM traffic assuming TPU-grade fusion.

    The CPU-backend HLO has no TPU fusion, so cost_analysis' "bytes
    accessed" counts every elementwise intermediate (and fp32 attention
    scores) as HBM traffic — a 5-20x overestimate of what a fused TPU
    program moves.  This model counts only the tensors that genuinely hit
    HBM on a fused TPU compile:

      * weights: each device reads its 1/tp slice; fwd + bwd + one remat
        re-read for training (3x), once for serving.
      * optimizer: local (ZeRO) shard m/v/param fp32 read+write.
      * activations: ~16 materialised (tokens_dev x width) tensors per
        block fwd, x2.5 with bwd+remat for training; attention scores are
        assumed fused (flash) and contribute nothing.
      * logits: tokens_dev x V/tp fp32, x3 for training.
      * decode: full KV-cache / SSM-state read per emitted token.
    """
    dt = 2  # bf16
    d = cfg.d_model
    N_param = cfg.param_count()
    N_active = cfg.active_param_count()
    tokens_dev = max(batch * (seq if kind != "decode" else 1), 1) / data
    w_active_dev = N_active * dt / tp

    if kind == "train":
        weights = 3.0 * w_active_dev
        opt = (N_param / (tp * (data if cfg.fsdp else 1))) * 4 * 6
        act_width = d if cfg.family != "ssm" else cfg.d_inner
        acts = cfg.n_layers * tokens_dev * act_width * dt * 16 * 2.5
        logits = tokens_dev * (cfg.vocab / tp) * 4 * 3
        return weights + opt + acts + logits
    if kind == "prefill":
        weights = 1.0 * w_active_dev
        act_width = d if cfg.family != "ssm" else cfg.d_inner
        acts = cfg.n_layers * tokens_dev * act_width * dt * 16
        cache = _cache_bytes(cfg, batch, seq, tp) / max(data, 1)
        return weights + acts + cache
    # decode: one token; whole weight slice + whole cache read
    cache = _cache_bytes(cfg, batch, seq, tp) / max(data, 1)
    logits = (batch / data) * cfg.vocab * 4
    return w_active_dev + cache + logits


def _cache_bytes(cfg, batch: int, seq: int, tp: int) -> float:
    """Global KV-cache / SSM-state bytes divided by tp (head-sharded)."""
    dt = 2
    if cfg.family == "ssm":
        st = cfg.n_layers * batch * cfg.ssm_heads * cfg.ssm_state * \
            cfg.ssm_head_dim * 4
        return st / tp
    if cfg.family == "hybrid":
        st = cfg.n_layers * batch * cfg.ssm_heads * cfg.ssm_state * \
            cfg.ssm_head_dim * 4
        n_seg = cfg.n_layers // cfg.hybrid_period
        kv_heads = max(cfg.n_kv, 16)
        kv = n_seg * batch * seq * kv_heads * cfg.hd * 2 * dt
        return (st + kv) / tp
    kv_heads = max(cfg.n_kv, 16)
    kv = cfg.n_layers * batch * seq * kv_heads * cfg.hd * 2 * dt
    if cfg.family == "encdec":
        kv += cfg.n_layers * batch * cfg.enc_seq * kv_heads * cfg.hd * 2 * dt
    return kv / tp


def model_flops_estimate(cfg, kind: str, batch: int, seq: int) -> float:
    """6*N_active*tokens for training, 2*N_active*tokens for prefill,
    2*N_active*batch (one token each) for decode; attention KV-cache reads
    are a memory (not flops) cost and are excluded, matching the standard
    MFU convention."""
    n_active = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n_active * batch * seq
    if kind == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch
