"""Model zoo: configs, layers and family implementations."""
from repro.models.common import HeadLayout, MeshInfo, ModelConfig, head_layout
from repro.models.transformer import build_model

__all__ = ["HeadLayout", "MeshInfo", "ModelConfig", "head_layout",
           "build_model"]
