"""Per-rank model layers with explicit collectives.

Every function in this module is written to execute **inside shard_map**:
inputs are local shards, tensor-parallel reductions are explicit
``lax.psum`` over the ``model`` axis, and FSDP parameter gathers are
explicit ``lax.all_gather`` over the data axes.  This keeps the collective
schedule fully under our control (DESIGN.md section 5) so the roofline's
collective term is exactly what we wrote, not what a partitioner guessed.

Conventions:
  d   = model width (replicated activations)
  B_l = per-rank batch, S = sequence
  Attention weights are stored in the padded group-major head layout of
  models.common.head_layout; padded q heads have zero wq/wo rows so the
  function equals the unpadded architecture exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import HeadLayout, MeshInfo, ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Collective helpers
# ---------------------------------------------------------------------------

def psum_model(x, mi: MeshInfo):
    return lax.psum(x, mi.model_axis) \
        if (mi.model_size > 1 or mi.bound) else x


def pmax_model(x, mi: MeshInfo):
    return lax.pmax(x, mi.model_axis) \
        if (mi.model_size > 1 or mi.bound) else x


def model_rank(mi: MeshInfo):
    return lax.axis_index(mi.model_axis) \
        if (mi.model_size > 1 or mi.bound) else 0


def pvary_init(x, mi: MeshInfo):
    """Mark a freshly-created (zeros) scan carry as device-varying so
    check_rep/vma-tracked shard_map accepts it as loop carry alongside
    varying data (no-op outside shard_map)."""
    axes = tuple(mi.data_axes) if (mi.data_size > 1 or mi.bound) else ()
    if mi.model_size > 1 or mi.bound:
        axes = axes + (mi.model_axis,)
    if not axes:
        return x
    return jax.tree.map(lambda a: lax.pvary(a, axes), x)


def gather_fsdp(p: Params, plan: Dict[str, Any], mi: MeshInfo) -> Params:
    """All-gather FSDP-sharded parameter leaves along their sharded dim.

    ``plan`` mirrors the structure of ``p``; each leaf is either -1
    (replicated over data) or the int dim that is sharded over the data
    axes.  AD transposes the gather into a reduce-scatter, which is exactly
    ZeRO gradient semantics.
    """
    if mi.data_size <= 1 and not mi.bound:
        return p

    def gather_leaf(leaf, dim):
        if dim is None or dim < 0:
            return leaf
        out = leaf
        for ax in mi.data_axes:
            out = lax.all_gather(out, ax, axis=dim, tiled=True)
        return out

    return jax.tree.map(gather_leaf, p, plan)


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(dt) * scale


def rms_norm_sharded(x, scale, eps: float, mi: MeshInfo, full_width: int):
    """RMSNorm over a width-sharded activation (sum of squares via psum)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    ssq = psum_model(jnp.sum(x32 * x32, axis=-1, keepdims=True), mi)
    var = ssq / full_width
    return (x32 * lax.rsqrt(var + eps)).astype(dt) * scale


def silu(x):
    return x * jax.nn.sigmoid(x)


def rope_tables(positions, hd: int, theta: float, dtype):
    """positions (..., S) -> cos/sin (..., S, hd//2)."""
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x (B, S, H, hd); cos/sin (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def sinusoid_pos_emb(S: int, d: int, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (1.0e4 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

NEG_INF = -1.0e30


def _mask_bias(sq, sk, q_off, mask_mode: str, prefix: int, dtype):
    """(sq, sk) additive mask.  mask_mode: causal | full | prefix."""
    if mask_mode == "full":
        return jnp.zeros((sq, sk), dtype)
    qi = q_off + jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    causal = kj <= qi
    if mask_mode == "prefix":
        causal = causal | (kj < prefix)
    return jnp.where(causal, 0.0, NEG_INF).astype(dtype)


def dense_attention(q, k, v, *, mask_mode="causal", prefix=0, q_off=0):
    """q (B,S,G,Qg,D), k/v (B,T,G,D) -> (B,S,G,Qg,D).  fp32 softmax."""
    B, S, G, Qg, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5
    scores = jnp.einsum("bsgqd,btgd->bgqst", q, k).astype(jnp.float32)
    scores = scores * scale + _mask_bias(S, T, q_off, mask_mode, prefix,
                                         jnp.float32)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgqst,btgd->bsgqd", p, v)


def flash_attention(q, k, v, *, mask_mode="causal", prefix=0,
                    chunk_q=1024, chunk_k=1024, static_steps=False,
                    mi: Optional[MeshInfo] = None):
    """Memory-bounded attention: scan over q chunks, inner fori over kv
    chunks with online softmax.  Same signature/layout as dense_attention.

    static_steps=True uses a fixed kv-chunk count (reverse-mode
    differentiable; ~2x causal flops).  False skips above-diagonal chunks
    (forward-only paths: prefill).
    """
    B, S, G, Qg, D = q.shape
    T_real = k.shape[1]
    cq = min(chunk_q, S)
    ck = min(chunk_k, T_real)
    assert S % cq == 0, (S, cq)
    if T_real % ck:  # pad KV to a chunk multiple; padding is masked out
        pad = ck - T_real % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    T = k.shape[1]
    nq, nk = S // cq, T // ck
    scale = D ** -0.5
    qr = q.reshape(B, nq, cq, G, Qg, D).transpose(1, 0, 2, 3, 4, 5)

    def q_chunk(_, qi_q):
        qi, qc = qi_q  # qc (B, cq, G, Qg, D)
        q_off = qi * cq
        m0 = jnp.full((B, G, Qg, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, Qg, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, G, Qg, D), jnp.float32)
        if mi is not None:
            m0, l0, a0 = pvary_init((m0, l0, a0), mi)

        def kv_step(kj, carry):
            m, l, acc = carry
            ks = lax.dynamic_slice_in_dim(k, kj * ck, ck, axis=1)
            vs = lax.dynamic_slice_in_dim(v, kj * ck, ck, axis=1)
            s = jnp.einsum("bsgqd,btgd->bgqst", qc, ks).astype(jnp.float32)
            s = s * scale
            qi_idx = q_off + jnp.arange(cq)[:, None]
            kj_idx = kj * ck + jnp.arange(ck)[None, :]
            if mask_mode == "causal":
                ok = kj_idx <= qi_idx
            elif mask_mode == "prefix":
                ok = (kj_idx <= qi_idx) | (kj_idx < prefix)
            else:
                ok = jnp.ones((cq, ck), bool)
            ok = ok & (kj_idx < T_real)  # exclude KV padding
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bgqst,btgd->bsgqd", p.astype(q.dtype), vs).astype(jnp.float32)
            return m_new, l_new, acc

        if mask_mode == "causal" and not static_steps:
            # only kv chunks up to the diagonal contribute
            n_steps = qi + 1 if nq == nk else nk
        else:
            n_steps = nk
        m, l, acc = lax.fori_loop(0, n_steps, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, out.astype(q.dtype)

    _, out = lax.scan(q_chunk, None, (jnp.arange(nq), qr))
    # out (nq, B, cq, G, Qg, D) -> (B, S, G, Qg, D)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, G, Qg, D)


def decode_attention(q, k_cache, v_cache, pos):
    """q (B,1,G,Qg,D); caches (B,Smax,G,D); pos (B,) current index.
    Attends positions <= pos."""
    B, _, G, Qg, D = q.shape
    Smax = k_cache.shape[1]
    scale = D ** -0.5
    s = jnp.einsum("bsgqd,btgd->bgqst", q, k_cache).astype(jnp.float32)
    s = s * scale
    ok = jnp.arange(Smax)[None, :] <= pos[:, None]  # (B, Smax)
    s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bgqst,btgd->bsgqd", p, v_cache)


# ---------------------------------------------------------------------------
# Attention layer (projections + TP collectives + cache plumbing)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AttnCache:
    k: jax.Array  # (B, Smax, kv_local, hd)
    v: jax.Array
    pos: jax.Array  # (B,) int32 next write index


def attn_project_qkv(p: Params, x, layout: HeadLayout, *, qkv_bias: bool):
    B, S, _ = x.shape
    hd = p["wq"].shape[1] // layout.hq_local
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, layout.hq_local, hd)
    k = k.reshape(B, S, layout.kv_local, hd)
    v = v.reshape(B, S, layout.kv_local, hd)
    return q, k, v


def _group_q(q, layout: HeadLayout):
    """(B,S,Hql,hd) -> (B,S,G,Qg,hd) grouped by local kv head."""
    B, S, Hql, hd = q.shape
    return q.reshape(B, S, layout.kv_local, layout.ql_per_kv, hd)


def attn_layer(
    p: Params,
    x,
    mi: MeshInfo,
    layout: HeadLayout,
    cfg: ModelConfig,
    *,
    mode: str = "train",          # train | prefill | decode
    mask_mode: str = "causal",
    prefix: int = 0,
    positions=None,               # (B, S) absolute positions for RoPE
    cache: Optional[AttnCache] = None,
    use_rope: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
):
    """Full GQA attention layer.  Returns (out (B,S,d), new_cache | None)."""
    B, S, _ = x.shape
    hd = cfg.hd
    q, k, v = attn_project_qkv(p, x, layout, qkv_bias=cfg.qkv_bias)
    if kv_override is not None:
        k, v = kv_override
    if use_rope and kv_override is None:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        cos, sin = rope_tables(positions, hd, cfg.rope_theta, x.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if mode == "decode":
        assert cache is not None and S == 1
        kc = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache.k, k, cache.pos)
        vc = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache.v, v, cache.pos)
        new_cache = AttnCache(k=kc, v=vc, pos=cache.pos + 1)
        o = decode_attention(_group_q(q, layout), kc, vc, cache.pos)
    else:
        if mode == "prefill":
            new_cache = AttnCache(
                k=k, v=v, pos=jnp.full((B,), S, jnp.int32))
        qg = _group_q(q, layout)
        T = k.shape[1]
        if max(S, T) > cfg.flash_threshold:
            o = flash_attention(qg, k, v, mask_mode=mask_mode, prefix=prefix,
                                static_steps=(mode == "train"), mi=mi)
        else:
            o = dense_attention(qg, k, v, mask_mode=mask_mode, prefix=prefix)
    o = o.reshape(B, S, layout.hq_local * hd)
    out = psum_model(o @ p["wo"], mi)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_glu(p: Params, x, mi: MeshInfo, *, gelu: bool = False,
            psum: bool = True):
    """SwiGLU / GeGLU with column-sharded gate+up, row-sharded down.
    psum=False returns the partial (pre-reduction) output so the caller
    can fuse several row-parallel reductions into one collective."""
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    act = jax.nn.gelu(g, approximate=True) if gelu else silu(g)
    out = (act * u) @ p["w_down"]
    return psum_model(out, mi) if psum else out


def mlp_plain(p: Params, x, mi: MeshInfo):
    """fc1 -> gelu -> fc2 (whisper-style)."""
    h = jax.nn.gelu(x @ p["w_fc1"] + p["b_fc1"], approximate=True)
    return psum_model(h @ p["w_fc2"], mi) + p["b_fc2"]


# ---------------------------------------------------------------------------
# Mixture of Experts (expert parallelism over the model axis)
# ---------------------------------------------------------------------------

def moe_layer(
    p: Params,
    x,
    mi: MeshInfo,
    cfg: ModelConfig,
    *,
    capacity_factor: float = 1.25,
    gelu: bool = False,
    psum: bool = True,
):
    """Sort-based grouped MoE.  Experts are sharded over the model axis;
    activations are replicated over it (Megatron invariant), so each rank
    routes *locally* to its own expert shard and a single psum merges
    expert outputs — the same collective pattern as a row-parallel matmul,
    no all-to-all required (DESIGN.md section 5).

    p: w_router (d, E) replicated; w_gate/w_up (E_local, d, f);
       w_down (E_local, f, d).
    x: (B, S, d) replicated over model.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_local = p["w_gate"].shape[0]
    N = B * S
    xf = x.reshape(N, d)

    logits = (xf @ p["w_router"]).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = lax.top_k(probs, k)  # (N, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    r = model_rank(mi)
    e_start = r * E_local
    flat_e = top_idx.reshape(N * k)
    flat_w = top_vals.reshape(N * k).astype(x.dtype)
    flat_tok = jnp.repeat(jnp.arange(N), k)

    local_e = flat_e - e_start
    mine = (local_e >= 0) & (local_e < E_local)
    key = jnp.where(mine, local_e, E_local)  # non-mine -> overflow bucket
    order = jnp.argsort(key)
    s_key = key[order]
    s_tok = flat_tok[order]
    s_w = flat_w[order]

    C = int(capacity_factor * k * N / E) + 1
    counts = jnp.bincount(key, length=E_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * k) - starts[s_key]
    keep = (s_key < E_local) & (pos < C)
    slot = jnp.where(keep, s_key * C + pos, 0)

    xb = jnp.zeros((E_local * C, d), x.dtype)
    xb = xb.at[slot].add(jnp.where(keep[:, None], xf[s_tok], 0.0))
    xb = xb.reshape(E_local, C, d)

    g = jnp.einsum("ecd,edf->ecf", xb, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xb, p["w_up"])
    act = jax.nn.gelu(g, approximate=True) if gelu else silu(g)
    yb = jnp.einsum("ecf,efd->ecd", act * u, p["w_down"])
    yb = yb.reshape(E_local * C, d)

    y = jnp.zeros((N, d), x.dtype)
    contrib = yb[slot] * (s_w * keep.astype(x.dtype))[:, None]
    y = y.at[s_tok].add(contrib)
    if psum:
        y = psum_model(y, mi)

    aux = _load_balance_loss(probs, top_idx, E)
    # mean over data shards: the right global statistic, and it keeps the
    # aux scan-carry device-invariant under vma-tracked shard_map
    if mi.data_size > 1 or mi.bound:
        aux = lax.psum(aux, mi.data_axes) / mi.data_size
    return y.reshape(B, S, d), aux


def _load_balance_loss(probs, top_idx, E):
    """Switch-style auxiliary load-balancing loss (replicated compute)."""
    N, k = top_idx.shape
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (N, k, E)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs) / k


# ---------------------------------------------------------------------------
# Mamba2 (SSD) layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SSMCache:
    state: jax.Array   # (B, H_local, d_state, P)
    conv_x: jax.Array  # (B, K-1, d_inner_local) - model-sharded channels
    conv_B: jax.Array  # (B, K-1, N) - replicated
    conv_C: jax.Array  # (B, K-1, N) - replicated


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv.  x (B,S,C), w (K,C).  With a cache (B,K-1,C)
    performs streaming update (S==1) and returns (y, new_cache)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
        return y, None
    xp = jnp.concatenate([cache, x], axis=1)  # (B, K-1+1, C)
    y = sum(xp[:, i:i + 1, :] * w[i] for i in range(K))
    return y, xp[:, 1:, :]


def _segsum_decay(da):
    """da (..., Q) per-step log-decays -> (..., Q, Q) lower-triangular
    exp(cumsum_i - cumsum_j) factors (j <= i).

    Mask BEFORE exponentiating: the j > i entries have positive diff that
    can overflow exp, and where(mask, inf, 0) produces 0*inf = NaN in the
    backward pass."""
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    Q = da.shape[-1]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(tri, diff, -jnp.inf)
    return jnp.exp(jnp.minimum(diff, 0.0))


def ssd_chunked(xs, dt, A, Bc, Cc, chunk: int, unroll: bool = False,
                mi: Optional[MeshInfo] = None):
    """Chunked state-space duality scan (Mamba2 alg. 1, fp32 state).

    xs (B,S,H,P), dt (B,S,H) [post-softplus], A (H,) [negative],
    Bc/Cc (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    B, S, H, P = xs.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    xs_ = xs.reshape(B, nc, Q, H, P)
    dt_ = dt.reshape(B, nc, Q, H)
    Bc_ = Bc.reshape(B, nc, Q, N)
    Cc_ = Cc.reshape(B, nc, Q, N)

    da = (dt_ * A[None, None, None, :]).astype(jnp.float32)  # (B,nc,Q,H)
    da_h = jnp.moveaxis(da, -1, 2)  # (B, nc, H, Q)
    Lmat = _segsum_decay(da_h)      # (B, nc, H, Q, Q)
    cs = jnp.cumsum(da_h, axis=-1)  # (B, nc, H, Q)
    total = cs[..., -1]             # (B, nc, H)

    # Intra-chunk (quadratic within the chunk, like a masked attention):
    CB = jnp.einsum("bcin,bcjn->bcij", Cc_.astype(jnp.float32),
                    Bc_.astype(jnp.float32))
    M = CB[:, :, None] * Lmat  # (B, nc, H, Q, Q)
    Mdt = M * jnp.moveaxis(dt_, -1, 2)[..., None, :].astype(jnp.float32)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", Mdt,
                         xs_.astype(jnp.float32))

    # Chunk state contribution: sum_j exp(cs_last - cs_j) dt_j B_j x_j^T
    w = jnp.exp(total[..., None] - cs) * jnp.moveaxis(dt_, -1, 2)
    Sc = jnp.einsum("bchj,bcjn,bcjhp->bchnp", w.astype(jnp.float32),
                    Bc_.astype(jnp.float32), xs_.astype(jnp.float32))

    decay_chunk = jnp.exp(total)  # (B, nc, H)

    def chunk_step(state, inp):
        Sc_c, dec_c, Cc_c, cs_c = inp
        # inter-chunk output from the incoming state
        y_in = jnp.einsum("bin,bhnp->bihp", Cc_c.astype(jnp.float32), state)
        y_in = y_in * jnp.exp(jnp.moveaxis(cs_c, 1, -1))[..., None]
        state = state * dec_c[..., None, None] + Sc_c
        return state, y_in

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    if mi is not None:
        state0 = pvary_init(state0, mi)
    xs_scan = (
        jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(decay_chunk, 1, 0),
        jnp.moveaxis(Cc_, 1, 0), jnp.moveaxis(cs, 1, 0),
    )
    state, y_inter = lax.scan(chunk_step, state0, xs_scan,
                              unroll=unroll or 1)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (B, nc, Q, H, P)
    y = (y_intra + y_inter).reshape(B, S, H, P).astype(xs.dtype)
    return y, state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD recurrence.  state (B,H,N,P) fp32; x_t (B,H,P);
    dt_t (B,H); B_t/C_t (B,N)."""
    dec = jnp.exp((dt_t * A[None, :]).astype(jnp.float32))  # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", B_t.astype(jnp.float32),
                     (x_t * dt_t[..., None]).astype(jnp.float32))
    state = state * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), state)
    return state, y.astype(x_t.dtype)


def mamba2_layer(
    p: Params,
    x,
    mi: MeshInfo,
    cfg: ModelConfig,
    *,
    mode: str = "train",
    cache: Optional[SSMCache] = None,
):
    """Mamba2 block, heads sharded over the model axis.

    p: w_z/w_x (d, di_local), w_B/w_C (d, N) [replicated], w_dt (d, H_local),
       dt_bias (H_local,), A_log (H_local,), D (H_local,),
       conv_x (K, di_local), conv_B/conv_C (K, N), norm (di_local,),
       w_out (di_local, d).
    Returns (out (B,S,d), new_cache | None).
    """
    B, S, d = x.shape
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    di_l = p["w_x"].shape[1]
    H_l = di_l // P

    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bc = x @ p["w_B"]
    Cc = x @ p["w_C"]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H_l,)

    if mode == "decode":
        assert cache is not None and S == 1
        xs, new_cx = _causal_conv(xs, p["conv_x"], cache.conv_x)
        Bc, new_cB = _causal_conv(Bc, p["conv_B"], cache.conv_B)
        Cc, new_cC = _causal_conv(Cc, p["conv_C"], cache.conv_C)
        xs, Bc, Cc = silu(xs), silu(Bc), silu(Cc)
        x_t = xs.reshape(B, H_l, P)
        state, y = ssd_decode_step(
            cache.state, x_t, dt.reshape(B, H_l), A,
            Bc.reshape(B, N), Cc.reshape(B, N))
        y = y + x_t * p["D"][None, :, None]
        y = y.reshape(B, 1, di_l)
        new_cache = SSMCache(state=state, conv_x=new_cx, conv_B=new_cB,
                             conv_C=new_cC)
    else:
        xs, _ = _causal_conv(xs, p["conv_x"])
        Bc, _ = _causal_conv(Bc, p["conv_B"])
        Cc, _ = _causal_conv(Cc, p["conv_C"])
        xs, Bc, Cc = silu(xs), silu(Bc), silu(Cc)
        xs_h = xs.reshape(B, S, H_l, P)
        y, state = ssd_chunked(xs_h, dt, A, Bc, Cc, cfg.ssm_chunk,
                               unroll=cfg.scan_unroll, mi=mi)
        y = y + xs_h * p["D"][None, None, :, None]
        y = y.reshape(B, S, di_l)
        new_cache = None
        if mode == "prefill":
            # carry the last K-1 pre-conv inputs for streaming decode
            k1 = cfg.ssm_conv - 1
            new_cache = SSMCache(
                state=state,
                conv_x=(x @ p["w_x"])[:, -k1:, :],
                conv_B=(x @ p["w_B"])[:, -k1:, :],
                conv_C=(x @ p["w_C"])[:, -k1:, :])

    # gated RMSNorm over the (sharded) inner width, then row-parallel out
    y = y * silu(z)
    y = rms_norm_sharded(y, p["norm"], cfg.norm_eps, mi, cfg.d_inner)
    out = psum_model(y @ p["w_out"], mi)
    return out, new_cache


# ---------------------------------------------------------------------------
# Vocab-sharded embedding / loss
# ---------------------------------------------------------------------------

def embed_lookup(table, ids, mi: MeshInfo):
    """table (V_local, d) vocab-sharded over model; ids (B, S) global."""
    V_local = table.shape[0]
    r = model_rank(mi)
    loc = ids - r * V_local
    ok = (loc >= 0) & (loc < V_local)
    loc = jnp.clip(loc, 0, V_local - 1)
    out = jnp.take(table, loc, axis=0)
    out = jnp.where(ok[..., None], out, 0.0)
    return psum_model(out, mi)


def lm_head_loss(h, table, labels, mi: MeshInfo, *, vocab_real: int,
                 z_weight: float = 0.0):
    """Cross-entropy with vocab-sharded logits (never materialises the full
    softmax).  h (B,S,d); table (V_local, d); labels (B,S) with -1 = pad.
    Returns (mean_loss, n_tokens)."""
    B, S, d = h.shape
    V_local = table.shape[0]
    r = model_rank(mi)
    hf = h.reshape(B * S, d)
    logits = (hf @ table.T).astype(jnp.float32)  # (N, V_local)
    gid = r * V_local + jnp.arange(V_local)
    logits = jnp.where((gid < vocab_real)[None, :], logits, NEG_INF)

    lab = labels.reshape(B * S)
    valid = lab >= 0
    lab = jnp.where(valid, lab, 0)

    # stability max carries no gradient (it cancels in the lse identity)
    mloc = lax.stop_gradient(jnp.max(logits, axis=-1))
    m = lax.stop_gradient(pmax_model(mloc, mi))
    se = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    lse = m + jnp.log(psum_model(se, mi))

    loc = lab - r * V_local
    ok = (loc >= 0) & (loc < V_local)
    loc = jnp.clip(loc, 0, V_local - 1)
    lab_logit = psum_model(
        jnp.where(ok, jnp.take_along_axis(
            logits, loc[:, None], axis=1)[:, 0], 0.0), mi)

    loss = (lse - lab_logit) * valid
    if z_weight:
        loss = loss + z_weight * (lse * valid) ** 2
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(loss) / n, n


def lm_head_logits(h, table, mi: MeshInfo, *, vocab_real: int):
    """Full (gathered) logits for serving.  h (B, S, d) -> (B, S, V_pad)."""
    logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
    if mi.model_size > 1:
        logits = lax.all_gather(logits, mi.model_axis, axis=-1, tiled=True)
    V = logits.shape[-1]
    gid = jnp.arange(V)
    return jnp.where((gid < vocab_real)[None, None, :], logits, NEG_INF)
