"""Model configuration and tensor-parallel layout arithmetic.

Every architecture in the assigned pool is described by one ``ModelConfig``.
The layout helpers here compute how attention heads, KV heads, experts and
vocab rows map onto the ``model`` mesh axis, including the zero-padded-head
scheme for archs whose head counts don't divide the TP degree (DESIGN.md):

* ``rep = tp // n_kv`` ranks share (and redundantly compute) one KV head
  when ``n_kv < tp``; ``kv_local = n_kv // tp`` KV heads live on each rank
  when ``n_kv >= tp``.
* Q heads are padded (zero-initialised wq/wo rows -> exact function
  preservation) so each rank owns ``hq_local`` whole heads whose KV group
  is rank-determined.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    gelu_glu: bool = False  # gemma-style GeGLU instead of SwiGLU
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    rope_theta: float = 1.0e4
    head_dim: int = 0  # 0 -> d_model // n_heads
    norm_eps: float = 1.0e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0  # arctic: parallel dense-FFN residual
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid: a shared attention block applied every `hybrid_period` layers
    hybrid_period: int = 0
    # encoder-decoder
    enc_layers: int = 0
    enc_seq: int = 0  # fixed encoder length (e.g. whisper frames)
    # VLM prefix-LM
    n_prefix: int = 0  # image-patch prefix tokens (bidirectional attention)
    # distribution / memory policy
    fsdp: bool = False
    fsdp_min_elems: int = 1 << 16  # leaves smaller than this stay replicated
    remat: bool = True
    # cost-probe knobs (launch.dryrun): XLA's cost_analysis counts a while
    # body once regardless of trip count, so probes compile fully-unrolled
    # reduced-depth variants and extrapolate linearly in depth.
    scan_unroll: bool = False
    flash_threshold: int = 4096  # above this seq len attention is chunked
    # numeric
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the 500k-context decode shape?"""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        total = 2 * V * d  # embed + head
        if self.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            att = d * self.n_heads * hd + 2 * d * self.n_kv * hd \
                + self.n_heads * hd * d
        else:
            att = 0
        per_layer = 0
        if self.family == "moe":
            per_layer = att + self.n_experts * 3 * d * f + d * self.n_experts
            if self.moe_dense_ff:
                per_layer += 3 * d * self.moe_dense_ff
        elif self.family in ("dense", "vlm"):
            per_layer = att + 3 * d * f
        elif self.family == "encdec":
            per_layer = att + 2 * d * f  # non-GLU mlp
        elif self.family == "ssm":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * di + 2 * N + H) + di * d
        elif self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * di + 2 * N + H) + di * d
        total += L * per_layer
        if self.family == "encdec":
            total += self.enc_layers * (att + 2 * d * f) + att * L  # cross
        if self.family == "hybrid" and self.hybrid_period:
            total += att + 3 * d * self.d_ff  # one shared block
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense = self.param_count() - L * self.n_experts * 3 * d * f
        return dense + L * self.top_k * 3 * d * f


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """Axis-name bookkeeping passed into per-rank (shard_map) code.

    bound=True means the code executes inside shard_map (axis names are
    bound), so collectives must run even over size-1 axes to keep vma
    tracking consistent; bound=False (unit tests calling per-rank code
    directly) skips them."""
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)
    model_size: int = 1
    data_size: int = 1  # product over data_axes (incl. pod)
    bound: bool = False

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return self.data_axes + (self.model_axis,)


def ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    """How GQA heads map to TP ranks (see module docstring)."""
    tp: int
    h_real: int      # real q heads
    n_kv: int        # real kv heads
    hq_local: int    # q heads per rank (padded layout)
    kv_local: int    # kv heads computed per rank
    rep: int         # ranks sharing one kv group (n_kv < tp)
    h_pad: int       # padded global q heads = tp * hq_local
    ql_per_kv: int   # local q heads per local kv head

    @property
    def kv_total(self) -> int:
        """Global stored kv heads (duplicated ``rep`` times when rep>1)."""
        return self.tp * self.kv_local


CANONICAL_TP = 16  # production model-axis size; padded layouts are always
                   # built for this so parameter shapes are mesh-independent
                   # (checkpoints reshard elastically across tp in {1,2,4,8,16})


def head_layout(cfg: ModelConfig, tp: int, *, n_heads=None, n_kv=None
                ) -> HeadLayout:
    H = n_heads if n_heads is not None else cfg.n_heads
    KV = n_kv if n_kv is not None else cfg.n_kv
    if H % KV:
        raise ValueError(f"{cfg.name}: n_heads {H} % n_kv {KV} != 0")
    canon = max(CANONICAL_TP, tp)
    if canon % tp:
        raise ValueError(f"{cfg.name}: canonical tp {canon} % tp {tp} != 0")
    if KV < canon:
        if canon % KV:
            raise ValueError(f"{cfg.name}: canon {canon} % n_kv {KV} != 0")
        rep_c, kv_local_c = canon // KV, 1
    else:
        if KV % canon:
            raise ValueError(f"{cfg.name}: n_kv {KV} % canon {canon} != 0")
        rep_c, kv_local_c = 1, KV // canon
    gs = H // KV
    hql_c = ceil_to(gs * kv_local_c, rep_c) // rep_c
    h_pad = canon * hql_c
    kv_total = canon * kv_local_c
    hq_local = h_pad // tp
    kv_local = kv_total // tp
    ql_per_kv = h_pad // kv_total
    return HeadLayout(tp=tp, h_real=H, n_kv=KV, hq_local=hq_local,
                      kv_local=kv_local, rep=max(1, kv_total // KV),
                      h_pad=h_pad, ql_per_kv=ql_per_kv)


def q_head_permutation(layout: HeadLayout) -> Sequence[int]:
    """Global padded-q-head slot -> real head index (or -1 for a zero pad).

    Slots are group-major: group g occupies slots [g*rep*hq_local,
    (g+1)*rep*hq_local) so that the ranks holding kv group g own exactly
    those q heads.
    """
    gs = layout.h_real // layout.n_kv
    slots_per_group = layout.h_pad // layout.n_kv
    out = []
    for g in range(layout.n_kv):
        heads = list(range(g * gs, (g + 1) * gs))
        heads += [-1] * (slots_per_group - gs)
        out.extend(heads)
    assert len(out) == layout.h_pad
    return out


def pad_vocab(vocab: int, tp: int) -> int:
    """Pad to a fixed 256 multiple (not tp) so embedding shapes are
    mesh-independent; padded rows are masked out of the softmax."""
    return ceil_to(vocab, 256)


def fsdp_dim(shape: Tuple[int, ...], fsdp_size: int,
             skip_dims: Sequence[int] = ()) -> Optional[int]:
    """Pick the first dimension divisible by the fsdp size (excluding
    model-sharded dims); None if no dim qualifies (param stays replicated
    over data)."""
    for i, s in enumerate(shape):
        if i in skip_dims:
            continue
        if s % fsdp_size == 0 and s >= fsdp_size:
            return i
    return None
