"""Model families: decoder LM (dense / MoE / VLM), encoder-decoder,
Mamba2 SSM and hybrid (Mamba2 + shared attention).

Every family exposes the same surface:

    model = build_model(cfg, mi)
    params = model.init(key)            # global arrays (or eval_shape'd)
    specs  = model.param_specs()        # PartitionSpec tree (same structure)
    model.loss(params_local, batch)     # per-rank, inside shard_map
    model.prefill(params_local, batch)  # -> (last_logits, cache)
    model.decode(params_local, batch, cache)  # -> (logits, cache)
    model.init_cache(B, Smax) / model.cache_specs(batch_sharded)

Layers are stacked on a leading L axis and scanned (`lax.scan`) so HLO size
is O(1 layer); each block body is rematerialised (`jax.checkpoint`) when
cfg.remat.  FSDP leaves are all-gathered per layer inside the scan body
(gather_fsdp), which AD turns into per-layer reduce-scatter of grads
(ZeRO semantics).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.common import (
    HeadLayout, MeshInfo, ModelConfig, fsdp_dim, head_layout, pad_vocab,
    q_head_permutation,
)

Params = Dict[str, Any]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _norm_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def _dense_init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class _KeyGen:
    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, k = jax.random.split(self._key)
        return k


# ---------------------------------------------------------------------------
# Attention block param builders (shared by all attention-bearing families)
# ---------------------------------------------------------------------------

def attn_param_shapes(cfg: ModelConfig, lay: HeadLayout, n_layers: int):
    d, hd = cfg.d_model, cfg.hd
    sh = {
        "wq": (n_layers, d, lay.h_pad * hd),
        "wk": (n_layers, d, lay.kv_total * hd),
        "wv": (n_layers, d, lay.kv_total * hd),
        "wo": (n_layers, lay.h_pad * hd, d),
    }
    if cfg.qkv_bias:
        sh["bq"] = (n_layers, lay.h_pad * hd)
        sh["bk"] = (n_layers, lay.kv_total * hd)
        sh["bv"] = (n_layers, lay.kv_total * hd)
    return sh


def attn_param_specs(cfg: ModelConfig, stacked: bool = True):
    n = (None,) if stacked else ()
    sp = {
        "wq": P(*n, None, "model"),
        "wk": P(*n, None, "model"),
        "wv": P(*n, None, "model"),
        "wo": P(*n, "model", None),
    }
    if cfg.qkv_bias:
        sp["bq"] = P(*n, "model")
        sp["bk"] = P(*n, "model")
        sp["bv"] = P(*n, "model")
    return sp


def init_attn_params(kg: _KeyGen, cfg: ModelConfig, lay: HeadLayout,
                     n_layers: int, out_scale: float):
    """Random init with (a) zero columns/rows for padded q heads so the
    padded layout computes exactly the real architecture, (b) KV weights
    generated once per real head and *tiled* across replicating ranks so
    duplicates start (and, with grad sync, stay) identical."""
    d, hd = cfg.d_model, cfg.hd
    dt = _dt(cfg)
    perm = jnp.asarray(q_head_permutation(lay))  # (h_pad,) -> real or -1
    qmask = (perm >= 0).astype(dt)

    wq = _dense_init(kg(), (n_layers, d, lay.h_pad, hd), dt)
    wq = (wq * qmask[None, None, :, None]).reshape(n_layers, d, -1)
    wo = _dense_init(kg(), (n_layers, lay.h_pad, hd, d), dt, out_scale)
    wo = (wo * qmask[None, :, None, None]).reshape(n_layers, -1, d)

    def kv(key):
        real = _dense_init(key, (n_layers, d, lay.n_kv, hd), dt)
        w = jnp.repeat(real, lay.kv_total // lay.n_kv, axis=2)
        return w.reshape(n_layers, d, -1)

    p = {"wq": wq, "wk": kv(kg()), "wv": kv(kg()), "wo": wo}
    if cfg.qkv_bias:
        bq = _dense_init(kg(), (n_layers, lay.h_pad, hd), dt)
        p["bq"] = (bq * qmask[None, :, None]).reshape(n_layers, -1)
        for nm in ("bk", "bv"):
            real = _dense_init(kg(), (n_layers, lay.n_kv, hd), dt)
            p[nm] = jnp.repeat(real, lay.kv_total // lay.n_kv,
                               axis=1).reshape(n_layers, -1)
    return p


def kv_duplication(cfg: ModelConfig, lay: HeadLayout) -> Dict[str, int]:
    """Param-name -> replication factor for cross-duplicate grad averaging
    (see optim.sync_duplicated_grads)."""
    rep = lay.kv_total // lay.n_kv
    if rep <= 1:
        return {}
    names = ["wk", "wv"] + (["bk", "bv"] if cfg.qkv_bias else [])
    return {n: rep for n in names}


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------

class BaseModel:
    def __init__(self, cfg: ModelConfig, mi: MeshInfo):
        self.cfg = cfg
        self.mi = mi
        self.tp = mi.model_size
        self.v_pad = pad_vocab(cfg.vocab, self.tp)
        self.fsdp_size = mi.data_size if cfg.fsdp else 1

    # -- fsdp plans ---------------------------------------------------------
    def _plan(self, shapes: Dict[str, Tuple[int, ...]],
              specs: Dict[str, P], stacked: bool,
              min_elems: Optional[int] = None) -> Dict[str, Any]:
        """Plan dims are in *sliced per-layer, per-model-rank local*
        coordinates (what gather_fsdp sees inside the scan body).
        -1 = not FSDP-sharded (replicated over data)."""
        import math as _math
        if min_elems is None:
            min_elems = self.cfg.fsdp_min_elems
        plan = {}
        for name, shape in shapes.items():
            if self.fsdp_size <= 1:
                plan[name] = -1
                continue
            spec = specs[name]
            local = list(shape)
            skip = set()
            for i, ax in enumerate(tuple(spec)):
                axes = ax if isinstance(ax, tuple) else (ax,)
                if "model" in axes:
                    local[i] //= self.tp
                    skip.add(i)
            if stacked:
                local = local[1:]
                skip = {i - 1 for i in skip if i > 0}
                skip.add(-99)  # nothing
            if _math.prod(local) < min_elems:
                plan[name] = -1
                continue
            dim = fsdp_dim(tuple(local), self.fsdp_size,
                           skip_dims=tuple(skip))
            plan[name] = -1 if dim is None else dim
        return plan

    def _merge_fsdp_specs(self, specs: Dict[str, P], plans: Dict[str, Any],
                          shapes: Dict[str, Tuple[int, ...]],
                          offset: int) -> Dict[str, P]:
        """Insert the data-axes FSDP sharding into the model-parallel spec
        at the plan's dim (+offset for the stacked-L dim)."""
        if self.fsdp_size <= 1:
            return specs
        out = {}
        for name, sp in specs.items():
            dim = plans.get(name, -1)
            if dim is None or dim < 0:
                out[name] = sp
                continue
            g = dim + offset
            rank = len(shapes[name])
            entries = list(sp) + [None] * (rank - len(tuple(sp)))
            assert entries[g] is None, (name, entries, g)
            entries[g] = self.mi.data_axes
            out[name] = P(*entries)
        return out

    def full_param_specs(self):
        """param_specs() with FSDP data-axis sharding merged in."""
        raise NotImplementedError

    def loss(self, params, batch):  # per-rank
        raise NotImplementedError

    def prefill(self, params, batch):
        raise NotImplementedError

    def decode(self, params, batch, cache):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Decoder-only LM: dense / MoE / VLM (prefix-LM)
# ---------------------------------------------------------------------------

class DecoderLM(BaseModel):
    def __init__(self, cfg: ModelConfig, mi: MeshInfo):
        super().__init__(cfg, mi)
        self.lay = head_layout(cfg, self.tp)
        self.e_local = cfg.n_experts // self.tp if cfg.n_experts else 0
        if cfg.n_experts and cfg.n_experts % self.tp:
            raise ValueError(f"{cfg.name}: n_experts % tp != 0")

    # -- params -------------------------------------------------------------
    def _block_shapes(self):
        cfg, lay, Lr = self.cfg, self.lay, self.cfg.n_layers
        d, f = cfg.d_model, cfg.d_ff
        sh = dict(attn_param_shapes(cfg, lay, Lr))
        sh["ln1"] = (Lr, d)
        sh["ln2"] = (Lr, d)
        if cfg.n_experts:
            sh["w_router"] = (Lr, d, cfg.n_experts)
            sh["w_gate"] = (Lr, cfg.n_experts, d, f)
            sh["w_up"] = (Lr, cfg.n_experts, d, f)
            sh["w_down"] = (Lr, cfg.n_experts, f, d)
            if cfg.moe_dense_ff:
                df = cfg.moe_dense_ff
                sh["dw_gate"] = (Lr, d, df)
                sh["dw_up"] = (Lr, d, df)
                sh["dw_down"] = (Lr, df, d)
        else:
            sh["w_gate"] = (Lr, d, f)
            sh["w_up"] = (Lr, d, f)
            sh["w_down"] = (Lr, f, d)
        return sh

    def _block_specs(self):
        cfg = self.cfg
        sp = dict(attn_param_specs(cfg))
        sp["ln1"] = P(None, None)
        sp["ln2"] = P(None, None)
        if cfg.n_experts:
            sp["w_router"] = P(None, None, None)
            sp["w_gate"] = P(None, "model", None, None)
            sp["w_up"] = P(None, "model", None, None)
            sp["w_down"] = P(None, "model", None, None)
            if cfg.moe_dense_ff:
                sp["dw_gate"] = P(None, None, "model")
                sp["dw_up"] = P(None, None, "model")
                sp["dw_down"] = P(None, "model", None)
        else:
            sp["w_gate"] = P(None, None, "model")
            sp["w_up"] = P(None, None, "model")
            sp["w_down"] = P(None, "model", None)
        return sp

    def param_specs(self):
        sp = {
            "emb": P("model", None),
            "lm_head": P("model", None),
            "final_norm": P(None),
            "blocks": self._block_specs(),
        }
        if self.cfg.family == "vlm":
            sp["vis_proj"] = P(None, "model")
            sp["vis_out"] = P("model", None)
        return sp

    def block_plan(self):
        return self._plan(self._block_shapes(), self._block_specs(),
                          stacked=True)

    def top_plan(self):
        shapes = {"emb": (self.v_pad, self.cfg.d_model),
                  "lm_head": (self.v_pad, self.cfg.d_model)}
        specs = {"emb": P("model", None), "lm_head": P("model", None)}
        return self._plan(shapes, specs, stacked=False)

    def init(self, key):
        cfg, lay = self.cfg, self.lay
        kg = _KeyGen(key)
        dt = _dt(cfg)
        d, f, Lr = cfg.d_model, cfg.d_ff, cfg.n_layers
        out_scale = 0.02 / (2 * Lr) ** 0.5
        blocks: Params = init_attn_params(kg, cfg, lay, Lr, out_scale)
        blocks["ln1"] = _norm_init(kg(), (Lr, d), dt)
        blocks["ln2"] = _norm_init(kg(), (Lr, d), dt)
        if cfg.n_experts:
            E = cfg.n_experts
            blocks["w_router"] = _dense_init(kg(), (Lr, d, E), dt)
            blocks["w_gate"] = _dense_init(kg(), (Lr, E, d, f), dt)
            blocks["w_up"] = _dense_init(kg(), (Lr, E, d, f), dt)
            blocks["w_down"] = _dense_init(kg(), (Lr, E, f, d), dt, out_scale)
            if cfg.moe_dense_ff:
                df = cfg.moe_dense_ff
                blocks["dw_gate"] = _dense_init(kg(), (Lr, d, df), dt)
                blocks["dw_up"] = _dense_init(kg(), (Lr, d, df), dt)
                blocks["dw_down"] = _dense_init(kg(), (Lr, df, d), dt,
                                                out_scale)
        else:
            blocks["w_gate"] = _dense_init(kg(), (Lr, d, f), dt)
            blocks["w_up"] = _dense_init(kg(), (Lr, d, f), dt)
            blocks["w_down"] = _dense_init(kg(), (Lr, f, d), dt, out_scale)
        p = {
            "emb": _dense_init(kg(), (self.v_pad, d), dt),
            "lm_head": _dense_init(kg(), (self.v_pad, d), dt),
            "final_norm": _norm_init(kg(), (d,), dt),
            "blocks": blocks,
        }
        if cfg.family == "vlm":
            p["vis_proj"] = _dense_init(kg(), (d, d), dt)
            p["vis_out"] = _dense_init(kg(), (d, d), dt)
        return p

    def kv_duplication(self):
        return {f"blocks/{k}": v
                for k, v in kv_duplication(self.cfg, self.lay).items()}

    def _top_shapes(self):
        return {"emb": (self.v_pad, self.cfg.d_model),
                "lm_head": (self.v_pad, self.cfg.d_model)}

    def full_param_specs(self):
        sp = self.param_specs()
        sp["blocks"] = self._merge_fsdp_specs(
            sp["blocks"], self.block_plan(), self._block_shapes(), offset=1)
        top = self._merge_fsdp_specs(
            {"emb": sp["emb"], "lm_head": sp["lm_head"]}, self.top_plan(),
            self._top_shapes(), offset=0)
        sp.update(top)
        return sp

    # -- forward ------------------------------------------------------------
    def _block(self, p, h, *, mode, mask_mode, prefix, positions, cache):
        cfg, mi = self.cfg, self.mi
        p = L.gather_fsdp(p, self.block_plan(), mi)
        a, new_cache = L.attn_layer(
            p, L.rms_norm(h, p["ln1"], cfg.norm_eps), mi, self.lay, cfg,
            mode=mode, mask_mode=mask_mode, prefix=prefix,
            positions=positions, cache=cache)
        h = h + a
        hn = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.n_experts:
            # capacity policy: training tolerates drops (GShard cf=1.25);
            # serving must not drop tokens — decode uses worst-case
            # capacity (token counts are tiny), prefill a generous 8x.
            cf = (1.25 if mode == "train"
                  else float(cfg.n_experts) if mode == "decode" else 8.0)
            if cfg.moe_dense_ff:
                # fused-residual reduction: the MoE combine and the dense
                # residual FFN add into the same residual stream, so their
                # partial (row-parallel) outputs are summed locally and
                # reduced with ONE psum instead of two (EXPERIMENTS.md
                # section Perf, arctic-480b iteration).
                y, aux = L.moe_layer(p, hn, mi, cfg, gelu=cfg.gelu_glu,
                                     psum=False, capacity_factor=cf)
                dp = {"w_gate": p["dw_gate"], "w_up": p["dw_up"],
                      "w_down": p["dw_down"]}
                y = y + L.mlp_glu(dp, hn, mi, gelu=cfg.gelu_glu, psum=False)
                y = L.psum_model(y, mi)
            else:
                y, aux = L.moe_layer(p, hn, mi, cfg, gelu=cfg.gelu_glu,
                                     capacity_factor=cf)
        else:
            y = L.mlp_glu(p, hn, mi, gelu=cfg.gelu_glu)
        return h + y, aux, new_cache

    def _trunk(self, params, h, *, mode, mask_mode, prefix, positions,
               caches=None):
        """Scan the block stack.  caches: stacked (L, ...) pytree or None."""
        cfg = self.cfg

        def body(carry, xs):
            h, aux = carry
            if caches is not None:
                p_l, cache_l = xs
                cache_l = L.AttnCache(**cache_l)
            else:
                p_l, cache_l = xs, None
            h, aux_l, new_cache = self._block(
                p_l, h, mode=mode, mask_mode=mask_mode, prefix=prefix,
                positions=positions, cache=cache_l)
            out = ({"k": new_cache.k, "v": new_cache.v, "pos": new_cache.pos}
                   if new_cache is not None else None)
            return (h, aux + aux_l), out

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (params["blocks"], caches) if caches is not None \
            else params["blocks"]
        (h, aux), new_caches = lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                        xs, unroll=cfg.scan_unroll or 1)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        return h, aux, new_caches

    def _embed(self, params, ids):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, ids, mi)
        if cfg.embed_scale:
            h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
        return h

    def _inputs(self, params, batch):
        """Token embedding (+ VLM patch prefix).  Returns (h, prefix_len,
        positions)."""
        cfg, mi = self.cfg, self.mi
        ids = batch["tokens"]
        h = self._embed(params, ids)
        prefix = 0
        if cfg.family == "vlm" and "patches" in batch:
            vp = params["vis_proj"]
            if mi.model_size > 1:
                # column-sharded projector + row-sharded output proj
                pe = batch["patches"] @ vp          # (B, P, d/tp)
                pe = L.psum_model(pe @ params["vis_out"], mi)
            else:
                pe = batch["patches"] @ vp @ params["vis_out"]
            h = jnp.concatenate([pe.astype(h.dtype), h], axis=1)
            prefix = batch["patches"].shape[1]
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return h, prefix, positions

    def loss(self, params, batch):
        cfg, mi = self.cfg, self.mi
        h, prefix, pos = self._inputs(params, batch)
        mask_mode = "prefix" if cfg.family == "vlm" else "causal"
        h, aux, _ = self._trunk(params, h, mode="train",
                                mask_mode=mask_mode, prefix=prefix,
                                positions=pos)
        labels = batch["labels"]
        if prefix:
            pad = jnp.full((labels.shape[0], prefix), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        head = L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             mi)["lm_head"]
        loss, n = L.lm_head_loss(h, head, labels, mi, vocab_real=cfg.vocab)
        return loss + 0.01 * aux / max(cfg.n_layers, 1), {
            "ce": loss, "aux": aux, "tokens": n}

    def prefill(self, params, batch):
        cfg, mi = self.cfg, self.mi
        h, prefix, pos = self._inputs(params, batch)
        mask_mode = "prefix" if cfg.family == "vlm" else "causal"
        h, _, caches = self._trunk(params, h, mode="prefill",
                                   mask_mode=mask_mode, prefix=prefix,
                                   positions=pos)
        head = L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             mi)["lm_head"]
        logits = L.lm_head_logits(h[:, -1:], head, mi, vocab_real=cfg.vocab)
        return logits[:, 0], caches

    def decode(self, params, batch, caches):
        cfg, mi = self.cfg, self.mi
        h = self._embed(params, batch["token"])
        if cfg.embed_scale:
            pass  # applied in _embed
        pos = batch["pos"][:, None]
        h, _, new_caches = self._trunk(params, h, mode="decode",
                                       mask_mode="causal", prefix=0,
                                       positions=pos, caches=caches)
        head = L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             mi)["lm_head"]
        logits = L.lm_head_logits(h, head, mi, vocab_real=cfg.vocab)
        return logits[:, 0], new_caches

    # -- caches -------------------------------------------------------------
    def init_cache(self, B: int, s_max: int):
        cfg, lay = self.cfg, self.lay
        dt = _dt(cfg)
        Lr = cfg.n_layers
        kv_total = lay.kv_total
        return {
            "k": jnp.zeros((Lr, B, s_max, kv_total, cfg.hd), dt),
            "v": jnp.zeros((Lr, B, s_max, kv_total, cfg.hd), dt),
            "pos": jnp.zeros((Lr, B), jnp.int32),
        }

    def cache_specs(self, batch_axes):
        return {
            "k": P(None, batch_axes, None, "model", None),
            "v": P(None, batch_axes, None, "model", None),
            "pos": P(None, batch_axes),
        }


# ---------------------------------------------------------------------------
# Mamba2 SSM LM
# ---------------------------------------------------------------------------

class SSMLM(BaseModel):
    def __init__(self, cfg: ModelConfig, mi: MeshInfo):
        super().__init__(cfg, mi)
        if cfg.ssm_heads % self.tp:
            raise ValueError(f"{cfg.name}: ssm heads % tp != 0")

    def _block_shapes(self):
        cfg, Lr = self.cfg, self.cfg.n_layers
        d, di, N, H, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                          cfg.ssm_heads, cfg.ssm_conv)
        return {
            "ln": (Lr, d),
            "w_z": (Lr, d, di), "w_x": (Lr, d, di),
            "w_B": (Lr, d, N), "w_C": (Lr, d, N),
            "w_dt": (Lr, d, H), "dt_bias": (Lr, H),
            "A_log": (Lr, H), "D": (Lr, H),
            "conv_x": (Lr, K, di), "conv_B": (Lr, K, N), "conv_C": (Lr, K, N),
            "norm": (Lr, di), "w_out": (Lr, di, d),
        }

    def _block_specs(self):
        return {
            "ln": P(None, None),
            "w_z": P(None, None, "model"), "w_x": P(None, None, "model"),
            "w_B": P(None, None, None), "w_C": P(None, None, None),
            "w_dt": P(None, None, "model"), "dt_bias": P(None, "model"),
            "A_log": P(None, "model"), "D": P(None, "model"),
            "conv_x": P(None, None, "model"),
            "conv_B": P(None, None, None), "conv_C": P(None, None, None),
            "norm": P(None, "model"), "w_out": P(None, "model", None),
        }

    def param_specs(self):
        return {
            "emb": P("model", None), "lm_head": P("model", None),
            "final_norm": P(None), "blocks": self._block_specs(),
        }

    def block_plan(self):
        return self._plan(self._block_shapes(), self._block_specs(),
                          stacked=True)

    def top_plan(self):
        shapes = {"emb": (self.v_pad, self.cfg.d_model),
                  "lm_head": (self.v_pad, self.cfg.d_model)}
        specs = {"emb": P("model", None), "lm_head": P("model", None)}
        return self._plan(shapes, specs, stacked=False)

    def init(self, key):
        cfg = self.cfg
        kg = _KeyGen(key)
        dt = _dt(cfg)
        out = {}
        for name, shape in self._block_shapes().items():
            if name in ("ln", "norm"):
                out[name] = _norm_init(kg(), shape, dt)
            elif name == "A_log":
                out[name] = jnp.log(jnp.broadcast_to(
                    jnp.linspace(1.0, 16.0, shape[1]), shape)).astype(
                        jnp.float32)
            elif name == "dt_bias":
                out[name] = jnp.full(shape, 0.5, jnp.float32)
            elif name == "D":
                out[name] = jnp.ones(shape, dt)
            elif name == "w_out":
                out[name] = _dense_init(kg(), shape, dt,
                                        0.02 / (2 * cfg.n_layers) ** 0.5)
            else:
                out[name] = _dense_init(kg(), shape, dt)
        return {
            "emb": _dense_init(kg(), (self.v_pad, cfg.d_model), dt),
            "lm_head": _dense_init(kg(), (self.v_pad, cfg.d_model), dt),
            "final_norm": _norm_init(kg(), (cfg.d_model,), dt),
            "blocks": out,
        }

    def kv_duplication(self):
        return {}

    def _top_shapes(self):
        return {"emb": (self.v_pad, self.cfg.d_model),
                "lm_head": (self.v_pad, self.cfg.d_model)}

    def full_param_specs(self):
        sp = self.param_specs()
        sp["blocks"] = self._merge_fsdp_specs(
            sp["blocks"], self.block_plan(), self._block_shapes(), offset=1)
        top = self._merge_fsdp_specs(
            {"emb": sp["emb"], "lm_head": sp["lm_head"]}, self.top_plan(),
            self._top_shapes(), offset=0)
        sp.update(top)
        return sp

    def _trunk(self, params, h, *, mode, caches=None):
        cfg, mi = self.cfg, self.mi
        plan = self.block_plan()

        def body(carry, xs):
            h = carry
            if caches is not None:
                p_l, cache_l = xs
                cache_l = L.SSMCache(**cache_l)
            else:
                p_l, cache_l = xs, None
            p_l = L.gather_fsdp(p_l, plan, mi)
            y, new_cache = L.mamba2_layer(
                p_l, L.rms_norm(h, p_l["ln"], cfg.norm_eps), mi, cfg,
                mode=mode, cache=cache_l)
            out = ({"state": new_cache.state, "conv_x": new_cache.conv_x,
                    "conv_B": new_cache.conv_B, "conv_C": new_cache.conv_C}
                   if new_cache is not None else None)
            return h + y, out

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (params["blocks"], caches) if caches is not None \
            else params["blocks"]
        h, new_caches = lax.scan(body, h, xs, unroll=cfg.scan_unroll or 1)
        return L.rms_norm(h, params["final_norm"], cfg.norm_eps), new_caches

    def _head(self, params, h):
        return L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             self.mi)["lm_head"]

    def loss(self, params, batch):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, batch["tokens"], mi)
        h, _ = self._trunk(params, h, mode="train")
        loss, n = L.lm_head_loss(h, self._head(params, h), batch["labels"],
                                 mi, vocab_real=cfg.vocab)
        return loss, {"ce": loss, "tokens": n}

    def prefill(self, params, batch):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, batch["tokens"], mi)
        h, caches = self._trunk(params, h, mode="prefill")
        logits = L.lm_head_logits(h[:, -1:], self._head(params, h), mi,
                                  vocab_real=cfg.vocab)
        return logits[:, 0], caches

    def decode(self, params, batch, caches):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, batch["token"], mi)
        h, new_caches = self._trunk(params, h, mode="decode", caches=caches)
        logits = L.lm_head_logits(h, self._head(params, h), mi,
                                  vocab_real=cfg.vocab)
        return logits[:, 0], new_caches

    def init_cache(self, B: int, s_max: int):
        cfg = self.cfg
        dt = _dt(cfg)
        Lr = cfg.n_layers
        H, N, P_, di = (cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim,
                        cfg.d_inner)
        k1 = cfg.ssm_conv - 1
        return {
            "state": jnp.zeros((Lr, B, H, N, P_), jnp.float32),
            "conv_x": jnp.zeros((Lr, B, k1, di), dt),
            "conv_B": jnp.zeros((Lr, B, k1, N), dt),
            "conv_C": jnp.zeros((Lr, B, k1, N), dt),
        }

    def cache_specs(self, batch_axes):
        return {
            "state": P(None, batch_axes, "model", None, None),
            "conv_x": P(None, batch_axes, None, "model"),
            "conv_B": P(None, batch_axes, None, None),
            "conv_C": P(None, batch_axes, None, None),
        }


# ---------------------------------------------------------------------------
# Hybrid (zamba2): Mamba2 stack + one shared attention block every k layers
# ---------------------------------------------------------------------------

class HybridLM(SSMLM):
    def __init__(self, cfg: ModelConfig, mi: MeshInfo):
        super().__init__(cfg, mi)
        if cfg.n_layers % cfg.hybrid_period:
            raise ValueError("n_layers must divide by hybrid_period")
        self.n_seg = cfg.n_layers // cfg.hybrid_period
        self.lay = head_layout(cfg, self.tp)

    def _shared_shapes(self):
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        sh = dict(attn_param_shapes(cfg, self.lay, 1))
        sh = {k: v[1:] for k, v in sh.items()}  # unstacked
        sh.update({"ln1": (d,), "ln2": (d,), "w_gate": (d, f),
                   "w_up": (d, f), "w_down": (f, d)})
        return sh

    def _shared_specs(self):
        sp = dict(attn_param_specs(self.cfg, stacked=False))
        sp.update({"ln1": P(None), "ln2": P(None),
                   "w_gate": P(None, "model"), "w_up": P(None, "model"),
                   "w_down": P("model", None)})
        return sp

    def param_specs(self):
        sp = super().param_specs()
        sp["shared"] = self._shared_specs()
        return sp

    def shared_plan(self):
        return self._plan(self._shared_shapes(), self._shared_specs(),
                          stacked=False)

    def full_param_specs(self):
        sp = super().full_param_specs()
        sp["shared"] = self._merge_fsdp_specs(
            sp["shared"], self.shared_plan(), self._shared_shapes(),
            offset=0)
        return sp

    def init(self, key):
        k1, k2 = jax.random.split(key)
        p = super().init(k1)
        cfg = self.cfg
        kg = _KeyGen(k2)
        dt = _dt(cfg)
        d, f = cfg.d_model, cfg.d_ff
        shared = {k: v[0] for k, v in init_attn_params(
            kg, cfg, self.lay, 1, 0.02 / (2 * self.n_seg) ** 0.5).items()}
        shared["ln1"] = _norm_init(kg(), (d,), dt)
        shared["ln2"] = _norm_init(kg(), (d,), dt)
        shared["w_gate"] = _dense_init(kg(), (d, f), dt)
        shared["w_up"] = _dense_init(kg(), (d, f), dt)
        shared["w_down"] = _dense_init(kg(), (f, d), dt,
                                       0.02 / (2 * self.n_seg) ** 0.5)
        p["shared"] = shared
        return p

    def kv_duplication(self):
        return {f"shared/{k}": v
                for k, v in kv_duplication(self.cfg, self.lay).items()}

    def _shared_block(self, params, h, *, mode, positions, cache):
        cfg, mi = self.cfg, self.mi
        p = L.gather_fsdp(params["shared"], self.shared_plan(), mi)
        a, new_cache = L.attn_layer(
            p, L.rms_norm(h, p["ln1"], cfg.norm_eps), mi, self.lay, cfg,
            mode=mode, mask_mode="causal", positions=positions, cache=cache)
        h = h + a
        h = h + L.mlp_glu(p, L.rms_norm(h, p["ln2"], cfg.norm_eps), mi)
        return h, new_cache

    def _trunk(self, params, h, *, mode, caches=None, positions=None):
        cfg, mi = self.cfg, self.mi
        plan = self.block_plan()
        per = cfg.hybrid_period
        n_seg = self.n_seg
        if positions is None:
            B, S = h.shape[0], h.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        # reshape stacked (L, ...) -> (n_seg, per, ...)
        seg_params = jax.tree.map(
            lambda a: a.reshape((n_seg, per) + a.shape[1:]), params["blocks"])

        def mamba_body(carry, xs):
            h = carry
            if caches is not None:
                p_l, cache_l = xs
                cache_l = L.SSMCache(**cache_l)
            else:
                p_l, cache_l = xs, None
            p_l = L.gather_fsdp(p_l, plan, mi)
            y, new_cache = L.mamba2_layer(
                p_l, L.rms_norm(h, p_l["ln"], cfg.norm_eps), mi, cfg,
                mode=mode, cache=cache_l)
            out = ({"state": new_cache.state, "conv_x": new_cache.conv_x,
                    "conv_B": new_cache.conv_B, "conv_C": new_cache.conv_C}
                   if new_cache is not None else None)
            return h + y, out

        if cfg.remat:
            mamba_body = jax.checkpoint(mamba_body)

        def seg_body(carry, xs):
            h = carry
            if caches is not None:
                p_seg, ssm_seg, attn_seg = xs
                h, new_ssm = lax.scan(mamba_body, h, (p_seg, ssm_seg),
                                      unroll=cfg.scan_unroll or 1)
                h, new_attn = self._shared_block(
                    params, h, mode=mode, positions=positions,
                    cache=L.AttnCache(**attn_seg))
                return h, (new_ssm, {"k": new_attn.k, "v": new_attn.v,
                                     "pos": new_attn.pos})
            h, new_ssm = lax.scan(mamba_body, h, xs,
                                  unroll=cfg.scan_unroll or 1)
            h, new_attn = self._shared_block(
                params, h, mode=mode, positions=positions, cache=None)
            out = ((new_ssm, {"k": new_attn.k, "v": new_attn.v,
                              "pos": new_attn.pos})
                   if new_attn is not None else new_ssm)
            return h, out

        if caches is not None:
            ssm_c, attn_c = caches["ssm"], caches["attn"]
            ssm_c = jax.tree.map(
                lambda a: a.reshape((n_seg, per) + a.shape[1:]), ssm_c)
            h, (new_ssm, new_attn) = lax.scan(
                seg_body, h, (seg_params, ssm_c, attn_c),
                unroll=cfg.scan_unroll or 1)
            new_ssm = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_ssm)
            new_caches = {"ssm": new_ssm, "attn": new_attn}
        else:
            h, out = lax.scan(seg_body, h, seg_params,
                              unroll=cfg.scan_unroll or 1)
            if mode == "prefill":
                new_ssm, new_attn = out
                new_ssm = jax.tree.map(
                    lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]),
                    new_ssm)
                new_caches = {"ssm": new_ssm, "attn": new_attn}
            else:
                new_caches = None
        return L.rms_norm(h, params["final_norm"], cfg.norm_eps), new_caches

    def prefill(self, params, batch):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, batch["tokens"], mi)
        h, caches = self._trunk(params, h, mode="prefill")
        logits = L.lm_head_logits(h[:, -1:], self._head(params, h), mi,
                                  vocab_real=cfg.vocab)
        return logits[:, 0], caches

    def decode(self, params, batch, caches):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, batch["token"], mi)
        pos = batch["pos"][:, None]
        h, new_caches = self._trunk(params, h, mode="decode", caches=caches,
                                    positions=pos)
        logits = L.lm_head_logits(h, self._head(params, h), mi,
                                  vocab_real=cfg.vocab)
        return logits[:, 0], new_caches

    def init_cache(self, B: int, s_max: int):
        cfg, lay = self.cfg, self.lay
        dt = _dt(cfg)
        ssm = super().init_cache(B, s_max)
        attn = {
            "k": jnp.zeros((self.n_seg, B, s_max, lay.kv_total, cfg.hd), dt),
            "v": jnp.zeros((self.n_seg, B, s_max, lay.kv_total, cfg.hd), dt),
            "pos": jnp.zeros((self.n_seg, B), jnp.int32),
        }
        return {"ssm": ssm, "attn": attn}

    def cache_specs(self, batch_axes):
        return {
            "ssm": super().cache_specs(batch_axes),
            "attn": {
                "k": P(None, batch_axes, None, "model", None),
                "v": P(None, batch_axes, None, "model", None),
                "pos": P(None, batch_axes),
            },
        }


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper)
# ---------------------------------------------------------------------------

class EncDecLM(BaseModel):
    """Whisper-style: stub conv frontend (precomputed frame embeddings in),
    bidirectional encoder, causal decoder with cross-attention."""

    def __init__(self, cfg: ModelConfig, mi: MeshInfo):
        super().__init__(cfg, mi)
        self.lay = head_layout(cfg, self.tp)

    def _enc_shapes(self):
        cfg, Lr = self.cfg, self.cfg.enc_layers
        d, f = cfg.d_model, cfg.d_ff
        sh = dict(attn_param_shapes(cfg, self.lay, Lr))
        sh.update({"ln1": (Lr, d), "ln2": (Lr, d),
                   "w_fc1": (Lr, d, f), "b_fc1": (Lr, f),
                   "w_fc2": (Lr, f, d), "b_fc2": (Lr, d)})
        return sh

    def _dec_shapes(self):
        cfg, Lr = self.cfg, self.cfg.n_layers
        d, f = cfg.d_model, cfg.d_ff
        sh = dict(attn_param_shapes(cfg, self.lay, Lr))
        xs = {f"x_{k}": v for k, v in
              attn_param_shapes(cfg, self.lay, Lr).items()}
        sh.update(xs)
        sh.update({"ln1": (Lr, d), "ln_x": (Lr, d), "ln2": (Lr, d),
                   "w_fc1": (Lr, d, f), "b_fc1": (Lr, f),
                   "w_fc2": (Lr, f, d), "b_fc2": (Lr, d)})
        return sh

    def _mlp_specs(self):
        return {"w_fc1": P(None, None, "model"), "b_fc1": P(None, "model"),
                "w_fc2": P(None, "model", None), "b_fc2": P(None, None)}

    def _enc_specs(self):
        sp = dict(attn_param_specs(self.cfg))
        sp.update({"ln1": P(None, None), "ln2": P(None, None)})
        sp.update(self._mlp_specs())
        return sp

    def _dec_specs(self):
        sp = dict(attn_param_specs(self.cfg))
        sp.update({f"x_{k}": v
                   for k, v in attn_param_specs(self.cfg).items()})
        sp.update({"ln1": P(None, None), "ln_x": P(None, None),
                   "ln2": P(None, None)})
        sp.update(self._mlp_specs())
        return sp

    def param_specs(self):
        return {
            "emb": P("model", None), "lm_head": P("model", None),
            "enc_norm": P(None), "final_norm": P(None),
            "enc": self._enc_specs(), "dec": self._dec_specs(),
        }

    def enc_plan(self):
        return self._plan(self._enc_shapes(), self._enc_specs(), stacked=True)

    def dec_plan(self):
        return self._plan(self._dec_shapes(), self._dec_specs(), stacked=True)

    def top_plan(self):
        shapes = {"emb": (self.v_pad, self.cfg.d_model),
                  "lm_head": (self.v_pad, self.cfg.d_model)}
        specs = {"emb": P("model", None), "lm_head": P("model", None)}
        return self._plan(shapes, specs, stacked=False)

    def full_param_specs(self):
        sp = self.param_specs()
        sp["enc"] = self._merge_fsdp_specs(
            sp["enc"], self.enc_plan(), self._enc_shapes(), offset=1)
        sp["dec"] = self._merge_fsdp_specs(
            sp["dec"], self.dec_plan(), self._dec_shapes(), offset=1)
        top_shapes = {"emb": (self.v_pad, self.cfg.d_model),
                      "lm_head": (self.v_pad, self.cfg.d_model)}
        top = self._merge_fsdp_specs(
            {"emb": sp["emb"], "lm_head": sp["lm_head"]}, self.top_plan(),
            top_shapes, offset=0)
        sp.update(top)
        return sp

    def init(self, key):
        cfg = self.cfg
        kg = _KeyGen(key)
        dt = _dt(cfg)
        d, f = cfg.d_model, cfg.d_ff

        def mlp(Lr, scale):
            return {"w_fc1": _dense_init(kg(), (Lr, d, f), dt),
                    "b_fc1": jnp.zeros((Lr, f), dt),
                    "w_fc2": _dense_init(kg(), (Lr, f, d), dt, scale),
                    "b_fc2": jnp.zeros((Lr, d), dt)}

        es = 0.02 / (2 * cfg.enc_layers) ** 0.5
        ds = 0.02 / (2 * cfg.n_layers) ** 0.5
        enc = init_attn_params(kg, cfg, self.lay, cfg.enc_layers, es)
        enc.update({"ln1": _norm_init(kg(), (cfg.enc_layers, d), dt),
                    "ln2": _norm_init(kg(), (cfg.enc_layers, d), dt)})
        enc.update(mlp(cfg.enc_layers, es))
        dec = init_attn_params(kg, cfg, self.lay, cfg.n_layers, ds)
        dec.update({f"x_{k}": v for k, v in init_attn_params(
            kg, cfg, self.lay, cfg.n_layers, ds).items()})
        dec.update({"ln1": _norm_init(kg(), (cfg.n_layers, d), dt),
                    "ln_x": _norm_init(kg(), (cfg.n_layers, d), dt),
                    "ln2": _norm_init(kg(), (cfg.n_layers, d), dt)})
        dec.update(mlp(cfg.n_layers, ds))
        return {
            "emb": _dense_init(kg(), (self.v_pad, d), dt),
            "lm_head": _dense_init(kg(), (self.v_pad, d), dt),
            "enc_norm": _norm_init(kg(), (d,), dt),
            "final_norm": _norm_init(kg(), (d,), dt),
            "enc": enc, "dec": dec,
        }

    def kv_duplication(self):
        dup = kv_duplication(self.cfg, self.lay)
        out = {}
        for k, v in dup.items():
            out[f"enc/{k}"] = v
            out[f"dec/{k}"] = v
            out[f"dec/x_{k}"] = v
        return out

    def _encode(self, params, frames):
        cfg, mi = self.cfg, self.mi
        B, S, d = frames.shape
        h = frames.astype(_dt(cfg)) + L.sinusoid_pos_emb(S, d, _dt(cfg))
        plan = self.enc_plan()

        def body(h, p_l):
            p_l = L.gather_fsdp(p_l, plan, mi)
            a, _ = L.attn_layer(
                p_l, L.rms_norm(h, p_l["ln1"], cfg.norm_eps), mi, self.lay,
                cfg, mode="train", mask_mode="full", use_rope=False)
            h = h + a
            h = h + L.mlp_plain(p_l, L.rms_norm(h, p_l["ln2"], cfg.norm_eps),
                                mi)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = lax.scan(body, h, params["enc"], unroll=cfg.scan_unroll or 1)
        return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, p_l, enc_out):
        """Per-layer cross KV from encoder output."""
        B, S, _ = enc_out.shape
        hd = self.cfg.hd
        k = (enc_out @ p_l["x_wk"]).reshape(B, S, self.lay.kv_local, hd)
        v = (enc_out @ p_l["x_wv"]).reshape(B, S, self.lay.kv_local, hd)
        if self.cfg.qkv_bias:
            k = k + p_l["x_bk"].reshape(1, 1, self.lay.kv_local, hd)
            v = v + p_l["x_bv"].reshape(1, 1, self.lay.kv_local, hd)
        return k, v

    def _dec_block(self, p_l, h, enc_out, *, mode, cache, cross_kv,
                   positions):
        cfg, mi = self.cfg, self.mi
        a, new_cache = L.attn_layer(
            p_l, L.rms_norm(h, p_l["ln1"], cfg.norm_eps), mi, self.lay, cfg,
            mode=mode, mask_mode="causal", positions=positions, cache=cache,
            use_rope=False)
        h = h + a
        if cross_kv is None:
            cross_kv = self._cross_kv(p_l, enc_out)
        xp = {k[2:]: v for k, v in p_l.items() if k.startswith("x_")}
        xa, _ = L.attn_layer(
            xp, L.rms_norm(h, p_l["ln_x"], cfg.norm_eps), mi, self.lay, cfg,
            mode="train", mask_mode="full", use_rope=False,
            kv_override=cross_kv)
        h = h + xa
        h = h + L.mlp_plain(p_l, L.rms_norm(h, p_l["ln2"], cfg.norm_eps), mi)
        return h, new_cache, cross_kv

    def _decode_trunk(self, params, tokens_h, enc_out, *, mode, caches,
                      positions):
        cfg, mi = self.cfg, self.mi
        plan = self.dec_plan()

        def body(h, xs):
            if caches is not None:
                p_l, c_l = xs
                cache_l = L.AttnCache(k=c_l["k"], v=c_l["v"], pos=c_l["pos"])
                cross = (c_l["xk"], c_l["xv"])
            else:
                p_l, cache_l, cross = xs, None, None
            p_l = L.gather_fsdp(p_l, plan, mi)
            h, new_cache, cross = self._dec_block(
                p_l, h, enc_out, mode=mode, cache=cache_l, cross_kv=cross,
                positions=positions)
            out = None
            if new_cache is not None:
                out = {"k": new_cache.k, "v": new_cache.v,
                       "pos": new_cache.pos, "xk": cross[0], "xv": cross[1]}
            return h, out

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (params["dec"], caches) if caches is not None else params["dec"]
        h, new_caches = lax.scan(body, tokens_h, xs,
                                 unroll=cfg.scan_unroll or 1)
        return L.rms_norm(h, params["final_norm"], cfg.norm_eps), new_caches

    def loss(self, params, batch):
        cfg, mi = self.cfg, self.mi
        enc_out = self._encode(params, batch["frames"])
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        B, S = batch["tokens"].shape
        h = L.embed_lookup(emb, batch["tokens"], mi)
        h = h + L.sinusoid_pos_emb(S, cfg.d_model, h.dtype)
        h, _ = self._decode_trunk(params, h, enc_out, mode="train",
                                  caches=None, positions=None)
        head = L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             mi)["lm_head"]
        loss, n = L.lm_head_loss(h, head, batch["labels"], mi,
                                 vocab_real=cfg.vocab)
        return loss, {"ce": loss, "tokens": n}

    def prefill(self, params, batch):
        cfg, mi = self.cfg, self.mi
        enc_out = self._encode(params, batch["frames"])
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        B, S = batch["tokens"].shape
        h = L.embed_lookup(emb, batch["tokens"], mi)
        h = h + L.sinusoid_pos_emb(S, cfg.d_model, h.dtype)
        h, caches = self._decode_trunk(params, h, enc_out, mode="prefill",
                                       caches=None, positions=None)
        head = L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             mi)["lm_head"]
        logits = L.lm_head_logits(h[:, -1:], head, mi, vocab_real=cfg.vocab)
        return logits[:, 0], caches

    def decode(self, params, batch, caches):
        cfg, mi = self.cfg, self.mi
        emb = L.gather_fsdp({"emb": params["emb"]},
                            {"emb": self.top_plan()["emb"]}, mi)["emb"]
        h = L.embed_lookup(emb, batch["token"], mi)
        pos_emb = L.sinusoid_pos_emb(int(caches["k"].shape[2]),
                                     cfg.d_model, h.dtype)
        h = h + jnp.take(pos_emb, batch["pos"], axis=0)[:, None]
        h, new_caches = self._decode_trunk(
            params, h, None, mode="decode", caches=caches,
            positions=batch["pos"][:, None])
        head = L.gather_fsdp({"lm_head": params["lm_head"]},
                             {"lm_head": self.top_plan()["lm_head"]},
                             mi)["lm_head"]
        logits = L.lm_head_logits(h, head, mi, vocab_real=cfg.vocab)
        return logits[:, 0], new_caches

    def init_cache(self, B: int, s_max: int):
        cfg, lay = self.cfg, self.lay
        dt = _dt(cfg)
        Lr = cfg.n_layers
        S_enc = cfg.enc_seq
        return {
            "k": jnp.zeros((Lr, B, s_max, lay.kv_total, cfg.hd), dt),
            "v": jnp.zeros((Lr, B, s_max, lay.kv_total, cfg.hd), dt),
            "pos": jnp.zeros((Lr, B), jnp.int32),
            "xk": jnp.zeros((Lr, B, S_enc, lay.kv_total, cfg.hd), dt),
            "xv": jnp.zeros((Lr, B, S_enc, lay.kv_total, cfg.hd), dt),
        }

    def cache_specs(self, batch_axes):
        kv = P(None, batch_axes, None, "model", None)
        return {"k": kv, "v": kv, "pos": P(None, batch_axes),
                "xk": kv, "xv": kv}


def build_model(cfg: ModelConfig, mi: MeshInfo) -> BaseModel:
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, mi)
    if cfg.family == "ssm":
        return SSMLM(cfg, mi)
    if cfg.family == "hybrid":
        return HybridLM(cfg, mi)
    if cfg.family == "encdec":
        return EncDecLM(cfg, mi)
    raise ValueError(cfg.family)
