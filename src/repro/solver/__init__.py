"""Unified front end for the batch 2-D LP solver stack.

One operation, many LPs, every backend::

    from repro.solver import SolverSpec

    solver = SolverSpec(backend="auto", shuffle=True).build()
    sol = solver.solve(batch)            # jit-cached per input shape
    one = solver.solve_one(A, b, c)      # single-LP convenience
    sol = jax.jit(solver)(batch)         # composable pure call
    sol = solver.solve(batch.pack())     # packed SoA batches solve
                                         # bit-identically, no repack

    # same problem, every backend, bit-for-bit comparable:
    sweep = [SolverSpec(backend=b, interpret=True if b == "kernel"
                        else None)
             for b in ("naive", "rgb", "kernel", "pdhg")]
    sols = [s.build().solve(batch) for s in sweep]

:class:`SolverSpec` is frozen and hashable — use it directly as a
static ``jax.jit`` argument or as an executable-cache key (the serving
layer's ``ExecSpec`` embeds one).

The exact Seidel backends (``naive``/``rgb``/``kernel``) answer to
machine precision at 2-D/small-m; ``backend="pdhg"`` is the restarted
first-order backend (:mod:`repro.pdhg`) that scales m into the
thousands and answers to a KKT tolerance.  ``backend="auto"`` routes
each input shape to the fastest *measured* backend when the tuning
table has entries.

Launch geometry left unset (``tile``/``chunk`` ``None``) is pinned per
input shape with the precedence *explicit > measured tuning table >
heuristic* (see :mod:`repro.tune` and
:meth:`SolverSpec.resolve_for_shape`).
"""
from repro.solver.solver import Solver, solve_with_spec
from repro.solver.spec import (BACKENDS, DEFAULT_M, SolverSpec,
                               get_solver)

__all__ = [
    "BACKENDS", "DEFAULT_M", "Solver", "SolverSpec", "get_solver",
    "solve_with_spec",
]
