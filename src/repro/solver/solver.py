"""`Solver` — a reusable, jit-cached executor for one :class:`SolverSpec`.

``spec.build()`` resolves ``"auto"`` choices against the current JAX
backend and returns a Solver that

* caches one jit-compiled callable per input shape (``solve``), so a
  stream of same-shaped batches compiles exactly once;
* stays composable: ``solver(batch)`` is a pure traceable function of
  the batch, safe under an outer ``jax.jit``/``jax.vmap``;
* offers ``solve_one(A, b, c)`` for the single-LP convenience case.

``solve_with_spec`` is the underlying pure function (spec in Python,
arrays traced).  Every layer — the benchmarks, the tuner, the serving
executables in ``serve_lp.sharding`` — runs through it, which is what
makes "same problem, every backend, bit-for-bit comparable" a
one-liner.

Both entry points accept either constraint layout: the AoS
:class:`~repro.core.lp.LPBatch` or the packed SoA
:class:`~repro.core.packed.PackedLPBatch`.  A packed batch stays packed
end-to-end — normalise/shuffle run in their packed-native forms, the
kernel backend consumes ``L`` directly, and the dense backends consume
the ``L`` component rows directly too (``seidel.solve_*_packed``; no
AoS round-trip anywhere in the trace).  The AoS entry slices its
normals into the same rows, so both layouts run the identical graph
and ``solve(pack(batch))`` is bit-identical to ``solve(batch)``.  (One
caveat: padding the constraint axis — in *either* layout — changes the
score shape ``shuffle`` draws from, so for ``shuffle=True`` specs the
identity needs matching ``m``; a padded batch still agrees on the
optimum to the usual tolerance, just not bit-for-bit.)

Launch geometry left unset on the spec (``tile``/``chunk`` ``None``)
is pinned here per input shape via
:meth:`~repro.solver.spec.SolverSpec.resolve_for_shape` — explicit
values win, then the measured :mod:`repro.tune` table for this device,
then the static heuristics.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from repro.core.lp import (LPBatch, LPSolution, normalize_batch,
                           shuffle_batch)
from repro.core.packed import (PackedLPBatch, normalize_packed, pack,
                               pad_packed, pad_packed_batch_dim,
                               shuffle_packed)
from repro.core.seidel import (solve_naive, solve_naive_packed, solve_rgb,
                               solve_rgb_packed)
from repro.pdhg import solve_pdhg, solve_pdhg_packed
from repro.solver.spec import RGB_DEFAULT_TILE, SolverSpec

AnyLPBatch = Union[LPBatch, PackedLPBatch]


def solve_with_spec(spec: SolverSpec, batch: AnyLPBatch,
                    key=None) -> LPSolution:
    """Solve ``batch`` (AoS or packed) per ``spec`` — the pure,
    trace-safe core.

    ``key`` overrides the spec's shuffle policy for this call; with
    ``key=None`` the batch is shuffled iff ``spec.shuffle`` (keyed by
    ``spec.seed``).
    """
    is_packed = isinstance(batch, PackedLPBatch)
    m = batch.m_pad if is_packed else batch.m
    spec = spec.resolve_for_shape(m, batch.batch)
    dt = jnp.dtype(spec.dtype)
    if key is None and spec.shuffle:
        key = jax.random.key(spec.seed)
    if is_packed:
        return _solve_packed(spec, batch, dt, key)
    # Cast each array (astype is the identity when already dt): A alone
    # matching must not let a mixed-dtype b or c leak through.
    batch = LPBatch(A=batch.A.astype(dt), b=batch.b.astype(dt),
                    c=batch.c.astype(dt), m_valid=batch.m_valid)
    if spec.normalize:
        batch = normalize_batch(batch)
    if key is not None:
        batch = shuffle_batch(key, batch)
    if spec.backend == "kernel":
        return _solve_kernel(spec, pack(batch))
    return _solve_dense(spec, batch)


def _solve_packed(spec: SolverSpec, pb: PackedLPBatch, dt,
                  key) -> LPSolution:
    """The packed-native pipeline: cast -> normalise -> shuffle without
    leaving the SoA layout, then hand the ``L`` rows straight to the
    backend (kernel and dense alike — no unpack in the trace)."""
    pb = PackedLPBatch(L=pb.L.astype(dt), c=pb.c.astype(dt),
                       m_valid=pb.m_valid)
    if spec.normalize:
        pb = normalize_packed(pb)
    if key is not None:
        pb = shuffle_packed(key, pb)
    if spec.backend == "kernel":
        return _solve_kernel(spec, pb)
    if spec.backend == "pdhg":
        return solve_pdhg_packed(pb, M=spec.M, tol=spec.tol,
                                 max_iters=spec.max_iters,
                                 iter_block=spec.iter_block,
                                 restart_period=spec.restart_period)
    if spec.backend == "naive":
        return solve_naive_packed(pb, M=spec.M)
    return solve_rgb_packed(pb, M=spec.M,
                            tile=spec.tile or RGB_DEFAULT_TILE,
                            chunk=spec.chunk or 0)


def _solve_dense(spec: SolverSpec, batch: LPBatch) -> LPSolution:
    if spec.backend == "pdhg":
        return solve_pdhg(batch, M=spec.M, tol=spec.tol,
                          max_iters=spec.max_iters,
                          iter_block=spec.iter_block,
                          restart_period=spec.restart_period)
    if spec.backend == "naive":
        return solve_naive(batch, M=spec.M)
    return solve_rgb(batch, M=spec.M,
                     tile=spec.tile or RGB_DEFAULT_TILE,
                     chunk=spec.chunk or 0)


def _solve_kernel(spec: SolverSpec, pb: PackedLPBatch) -> LPSolution:
    # Deferred import: kernels.ops wraps this module for its public
    # compatibility surface, so the dependency must point one way only.
    from repro.kernels.batch_lp import LANE, _pick_tile, rgb_pallas

    B = pb.batch
    pb = pad_packed(pb, -(-pb.m_pad // LANE) * LANE)
    tile = spec.tile or _pick_tile(pb.m_pad, B,
                                   itemsize=pb.L.dtype.itemsize)
    run = pad_packed_batch_dim(pb, -(-B // tile) * tile)
    x, feas = rgb_pallas(run.L, run.c, run.m_valid, M=spec.M, tile=tile,
                         chunk=spec.chunk, interpret=spec.interpret)
    x, feas = x[:B], feas[:B, 0]
    return LPSolution(
        x=x,
        feasible=feas.astype(bool),
        objective=jnp.einsum("bd,bd->b", pb.c.astype(x.dtype), x),
    )


class Solver:
    """Executor for one resolved :class:`SolverSpec`.

    Construct via ``spec.build()`` (or :func:`~repro.solver.spec.
    get_solver` for the process-wide cached instance).
    """

    def __init__(self, spec: SolverSpec):
        if not isinstance(spec, SolverSpec):
            raise TypeError(f"expected SolverSpec, got {type(spec)!r}")
        self.spec = spec.resolve()
        # ``backend="auto"`` stays "auto" on the *solving* spec so each
        # input shape can pick the fastest measured backend from the
        # tuning table at trace time (``self.spec`` above is the
        # introspection view and the choice on a table miss).  Note the
        # process-wide :func:`~repro.solver.spec.get_solver` cache keys
        # on the resolved spec, so it pins "auto" to the platform
        # default; build a Solver via ``spec.build()`` to keep the
        # shape-dependent behaviour.
        self._solve_spec = spec if spec.backend == "auto" else self.spec
        # jax.jit itself caches one compile per input shape/dtype; one
        # persistent wrapper per calling convention is all we need.
        # _shapes only tracks the distinct entries for introspection.
        self._jit_plain = jax.jit(
            lambda b: solve_with_spec(self._solve_spec, b))
        self._jit_keyed = jax.jit(
            lambda b, k: solve_with_spec(self._solve_spec, b, k))
        self._shapes = set()

    # -- composable entry point ------------------------------------------

    def __call__(self, batch: AnyLPBatch, key=None) -> LPSolution:
        """Pure function of ``(batch, key)`` — compose freely under an
        outer ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` transform."""
        return solve_with_spec(self._solve_spec, batch, key)

    # -- jit-cached host entry points ------------------------------------

    def solve(self, batch: AnyLPBatch, key=None) -> LPSolution:
        """Solve one batch (AoS or packed) through the per-shape
        compile cache."""
        arr = batch.L if isinstance(batch, PackedLPBatch) else batch.A
        self._shapes.add((type(batch).__name__, arr.shape,
                          str(arr.dtype), key is not None))
        if key is None:
            return self._jit_plain(batch)
        return self._jit_keyed(batch, key)

    def solve_one(self, A, b, c, key=None) -> LPSolution:
        """Solve a single LP (``A (m,2)``, ``b (m,)``, ``c (2,)``);
        returns an :class:`LPSolution` with the batch axis dropped."""
        from repro.core.lp import make_batch
        sol = self.solve(make_batch(A, b, c), key=key)
        return LPSolution(x=sol.x[0], feasible=sol.feasible[0],
                          objective=sol.objective[0])

    # -- introspection ----------------------------------------------------

    def cache_info(self) -> dict:
        """Distinct (shape, dtype, keyed) entries solved so far — each
        cost exactly one compile in the underlying jit caches."""
        return {"n_entries": len(self._shapes),
                "shapes": sorted(str(k) for k in self._shapes)}

    def __repr__(self) -> str:
        return f"Solver({self.spec!r})"
