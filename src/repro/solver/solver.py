"""`Solver` — a reusable, jit-cached executor for one :class:`SolverSpec`.

``spec.build()`` resolves ``"auto"`` choices against the current JAX
backend and returns a Solver that

* caches one jit-compiled callable per input shape (``solve``), so a
  stream of same-shaped batches compiles exactly once;
* stays composable: ``solver(batch)`` is a pure traceable function of
  the batch, safe under an outer ``jax.jit``/``jax.vmap``;
* offers ``solve_one(A, b, c)`` for the single-LP convenience case.

``solve_with_spec`` is the underlying pure function (spec in Python,
arrays traced).  Every layer — the ``core.solve_batch_lp`` deprecation
shim, ``kernels.ops``, the serving executables in
``serve_lp.sharding`` — runs through it, which is what makes "same
problem, every backend, bit-for-bit comparable" a one-liner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lp import (LPBatch, LPSolution, normalize_batch,
                           shuffle_batch)
from repro.core.seidel import solve_naive, solve_rgb
from repro.solver.spec import RGB_DEFAULT_TILE, SolverSpec


def solve_with_spec(spec: SolverSpec, batch: LPBatch,
                    key=None) -> LPSolution:
    """Solve ``batch`` per ``spec`` — the pure, trace-safe core.

    ``key`` overrides the spec's shuffle policy for this call; with
    ``key=None`` the batch is shuffled iff ``spec.shuffle`` (keyed by
    ``spec.seed``).
    """
    spec = spec.resolve()
    dt = jnp.dtype(spec.dtype)
    # Cast each array (astype is the identity when already dt): A alone
    # matching must not let a mixed-dtype b or c leak through.
    batch = LPBatch(A=batch.A.astype(dt), b=batch.b.astype(dt),
                    c=batch.c.astype(dt), m_valid=batch.m_valid)
    if spec.normalize:
        batch = normalize_batch(batch)
    if key is None and spec.shuffle:
        key = jax.random.key(spec.seed)
    if key is not None:
        batch = shuffle_batch(key, batch)
    if spec.backend == "naive":
        return solve_naive(batch, M=spec.M)
    if spec.backend == "rgb":
        return solve_rgb(batch, M=spec.M,
                         tile=spec.tile or RGB_DEFAULT_TILE,
                         chunk=spec.chunk)
    return _solve_kernel(spec, batch)


def _solve_kernel(spec: SolverSpec, batch: LPBatch) -> LPSolution:
    # Deferred import: kernels.ops wraps this module for its public
    # compatibility surface, so the dependency must point one way only.
    from repro.kernels.batch_lp import _pick_tile, rgb_pallas
    from repro.kernels.ops import _pad_batch_dim, pack_constraints

    L, c, mv = pack_constraints(batch)
    tile = spec.tile or _pick_tile(L.shape[-1], L.shape[0])
    L, c, mv, B = _pad_batch_dim(L, c, mv, tile)
    x, feas = rgb_pallas(L, c, mv, M=spec.M, tile=tile, chunk=spec.chunk,
                         interpret=spec.interpret)
    x, feas = x[:B], feas[:B, 0]
    return LPSolution(
        x=x,
        feasible=feas.astype(bool),
        objective=jnp.einsum("bd,bd->b", batch.c.astype(x.dtype), x),
    )


class Solver:
    """Executor for one resolved :class:`SolverSpec`.

    Construct via ``spec.build()`` (or :func:`~repro.solver.spec.
    get_solver` for the process-wide cached instance).
    """

    def __init__(self, spec: SolverSpec):
        if not isinstance(spec, SolverSpec):
            raise TypeError(f"expected SolverSpec, got {type(spec)!r}")
        self.spec = spec.resolve()
        # jax.jit itself caches one compile per input shape/dtype; one
        # persistent wrapper per calling convention is all we need.
        # _shapes only tracks the distinct entries for introspection.
        self._jit_plain = jax.jit(
            lambda b: solve_with_spec(self.spec, b))
        self._jit_keyed = jax.jit(
            lambda b, k: solve_with_spec(self.spec, b, k))
        self._shapes = set()

    # -- composable entry point ------------------------------------------

    def __call__(self, batch: LPBatch, key=None) -> LPSolution:
        """Pure function of ``(batch, key)`` — compose freely under an
        outer ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` transform."""
        return solve_with_spec(self.spec, batch, key)

    # -- jit-cached host entry points ------------------------------------

    def solve(self, batch: LPBatch, key=None) -> LPSolution:
        """Solve one batch through the per-shape compile cache."""
        self._shapes.add((batch.A.shape, str(batch.A.dtype),
                          key is not None))
        if key is None:
            return self._jit_plain(batch)
        return self._jit_keyed(batch, key)

    def solve_one(self, A, b, c, key=None) -> LPSolution:
        """Solve a single LP (``A (m,2)``, ``b (m,)``, ``c (2,)``);
        returns an :class:`LPSolution` with the batch axis dropped."""
        from repro.core.lp import make_batch
        sol = self.solve(make_batch(A, b, c), key=key)
        return LPSolution(x=sol.x[0], feasible=sol.feasible[0],
                          objective=sol.objective[0])

    # -- introspection ----------------------------------------------------

    def cache_info(self) -> dict:
        """Distinct (shape, dtype, keyed) entries solved so far — each
        cost exactly one compile in the underlying jit caches."""
        return {"n_entries": len(self._shapes),
                "shapes": sorted(str(k) for k in self._shapes)}

    def __repr__(self) -> str:
        return f"Solver({self.spec!r})"
