"""`SolverSpec` — the one frozen, hashable description of *how* to solve.

Every public entry point used to carry its own loose bag of kwargs
(``core.solve_batch_lp(method=..., tile=..., ...)``,
``kernels.ops.solve_batch_lp_kernel`` with a different signature and a
different ``normalize`` default, the serving scheduler re-threading
tile/M/interpret by hand).  A :class:`SolverSpec` replaces all of them:
it validates once at construction, hashes and compares by value — so it
can key executable caches and be passed as a static ``jax.jit``
argument — and builds a reusable :class:`~repro.solver.solver.Solver`
via :meth:`build`.

The *shuffle policy* lives in the spec rather than in a per-call kwarg:
``shuffle=True`` applies Seidel's randomised constraint order on every
solve, keyed by ``seed`` unless the caller passes an explicit key.  A
key passed at call time always wins, so ``shuffle=False`` specs can
still opt in per call (the old ``key=`` behaviour).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Optional

import jax

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.solver.solver import Solver

# Box bound default: "very large so as not to affect the optimum".
DEFAULT_M = 1.0e4

BACKENDS = ("naive", "rgb", "kernel", "auto")
DTYPES = ("float32", "float64")

# Backend-default tiles when ``tile=None``: the pure-JAX cooperative
# solver uses the paper-faithful warp-sized tile; the Pallas kernel
# picks a VMEM-budgeted tile per input shape at solve time.
RGB_DEFAULT_TILE = 32


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Full configuration of a batch 2-D LP solve.

    Parameters
    ----------
    backend:
        ``"naive"`` (divergence-emulating vmap baseline), ``"rgb"``
        (pure-JAX cooperative tiles), ``"kernel"`` (Pallas TPU kernel)
        or ``"auto"`` (kernel on TPU, rgb elsewhere — resolved against
        the running JAX backend by :meth:`resolve`/:meth:`build`).
    tile:
        problems per cooperative tile.  ``None`` means the backend
        default: 32 for ``rgb``, a VMEM-budgeted per-shape choice for
        ``kernel``; ignored by ``naive``.
    chunk:
        lane-chunk size for the chunked O(i) re-solve (0 = dense).
    M:
        box bound on both coordinates (must not bind at the optimum).
    normalize:
        scale every constraint to unit norm before solving (keeps every
        epsilon an absolute distance; strongly recommended).
    shuffle:
        apply Seidel's randomised constraint order on every solve,
        keyed by ``seed`` unless a per-call key is given.
    seed:
        key for ``shuffle=True`` when no per-call key overrides it.
    interpret:
        ``kernel`` backend only — run the Pallas kernel body in
        interpret mode.  ``None`` resolves to True on a CPU backend so
        the kernel stays runnable in tests/CI.
    dtype:
        solve precision, ``"float32"`` or ``"float64"`` (inputs are
        cast on entry).
    """

    backend: str = "auto"
    tile: Optional[int] = None
    chunk: int = 0
    M: float = DEFAULT_M
    normalize: bool = True
    shuffle: bool = False
    seed: int = 0
    interpret: Optional[bool] = None
    dtype: str = "float32"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of "
                f"{BACKENDS}")
        if self.tile is not None and (not isinstance(self.tile, int)
                                      or self.tile < 1):
            raise ValueError(f"tile={self.tile!r} must be a positive int "
                             "or None")
        if not isinstance(self.chunk, int) or self.chunk < 0:
            raise ValueError(f"chunk={self.chunk!r} must be an int >= 0")
        M = float(self.M)
        if not M > 0.0:
            raise ValueError(f"M={self.M!r} must be > 0")
        object.__setattr__(self, "M", M)
        if not isinstance(self.seed, int):
            raise ValueError(f"seed={self.seed!r} must be an int")
        dt = str(self.dtype)
        if dt not in DTYPES:
            raise ValueError(f"dtype={self.dtype!r}; expected one of "
                             f"{DTYPES}")
        object.__setattr__(self, "dtype", dt)

    # -- resolution ------------------------------------------------------

    @property
    def is_resolved(self) -> bool:
        """True once ``backend`` and ``interpret`` are concrete."""
        return self.backend != "auto" and self.interpret is not None

    def resolve(self, platform: Optional[str] = None) -> "SolverSpec":
        """Pin ``"auto"`` choices against the running JAX backend and
        canonicalise inert fields.

        Environment-dependent choices (``backend="auto"``,
        ``interpret=None``) become concrete; fields that cannot affect
        execution are pinned (``interpret`` off the kernel backend,
        ``seed`` when ``shuffle=False``, the rgb default ``tile``), so
        specs with identical execution plans resolve equal and share
        executable-cache entries.  The kernel backend keeps
        ``tile=None`` — there it means "pick a VMEM-budgeted tile per
        shape".
        """
        platform = platform or jax.default_backend()
        if self.dtype == "float64" and not jax.config.jax_enable_x64:
            raise ValueError(
                "dtype='float64' needs jax x64 enabled (set "
                "jax_enable_x64=True or JAX_ENABLE_X64=1); refusing to "
                "silently truncate the solve to float32")
        backend = self.backend
        if backend == "auto":
            backend = "kernel" if platform == "tpu" else "rgb"
        if backend == "kernel":
            interpret = (platform == "cpu" if self.interpret is None
                         else bool(self.interpret))
        else:
            interpret = False
        tile = self.tile
        if backend == "rgb" and tile is None:
            tile = RGB_DEFAULT_TILE
        seed = self.seed if self.shuffle else 0
        if (backend == self.backend and interpret == self.interpret
                and tile == self.tile and seed == self.seed):
            return self
        return dataclasses.replace(self, backend=backend,
                                   interpret=interpret, tile=tile,
                                   seed=seed)

    # -- construction of the runtime object ------------------------------

    def build(self) -> "Solver":
        """Resolve and wrap into a reusable :class:`Solver` (fresh
        instance; use :func:`get_solver` for a process-wide cached
        one)."""
        from repro.solver.solver import Solver  # deferred: import cycle
        return Solver(self)


@functools.lru_cache(maxsize=None)
def _cached_solver(spec: SolverSpec) -> "Solver":
    from repro.solver.solver import Solver  # deferred: import cycle
    return Solver(spec)


def get_solver(spec: SolverSpec) -> "Solver":
    """Process-wide ``spec -> Solver`` cache.

    Equal specs share one Solver — and therefore one per-shape compile
    cache — which is what makes the ``core.solve_batch_lp`` shim free
    of repeated jit setup and keeps sweeps like
    ``[get_solver(s).solve(batch) for s in sweep]`` cheap to re-run.
    """
    return _cached_solver(spec.resolve())
