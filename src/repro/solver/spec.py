"""`SolverSpec` — the one frozen, hashable description of *how* to solve.

Every public entry point used to carry its own loose bag of kwargs
(the historical ``method=``/``tile=`` call styles, since-retired
compat wrappers with conflicting ``normalize`` defaults, the serving
scheduler re-threading tile/M/interpret by hand).  A
:class:`SolverSpec` replaces all of them:
it validates once at construction, hashes and compares by value — so it
can key executable caches and be passed as a static ``jax.jit``
argument — and builds a reusable :class:`~repro.solver.solver.Solver`
via :meth:`build`.

The *shuffle policy* lives in the spec rather than in a per-call kwarg:
``shuffle=True`` applies Seidel's randomised constraint order on every
solve, keyed by ``seed`` unless the caller passes an explicit key.  A
key passed at call time always wins, so ``shuffle=False`` specs can
still opt in per call (the old ``key=`` behaviour).

Launch geometry (``tile``/``chunk``) is resolved in two stages.
:meth:`resolve` pins only environment-dependent fields (backend,
interpret) and leaves unset geometry as the sentinel ``None``;
:meth:`resolve_for_shape` — called wherever the input shape is known
(the solve core, the serving scheduler's per-bucket flush) — pins it
with the precedence **explicit > tuning table > heuristic**: values the
user set always win, otherwise the measured
:class:`repro.tune.TuningTable` for this device is consulted, and a
table miss falls back to the static defaults (never an error).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Optional

import jax

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.solver.solver import Solver

# Box bound default: "very large so as not to affect the optimum".
DEFAULT_M = 1.0e4

BACKENDS = ("naive", "rgb", "kernel", "pdhg", "auto")
DTYPES = ("float32", "float64")

# Spec knobs that only the first-order backend interprets; validation
# rejects them on any other backend so a typo'd spec fails loudly
# instead of silently ignoring a tolerance.
PDHG_ONLY_FIELDS = ("iter_block", "restart_period", "tol", "max_iters")

# Backend-default tiles when ``tile=None`` and the tuning table has no
# entry: the pure-JAX cooperative solver uses the paper-faithful
# warp-sized tile; the Pallas kernel picks a VMEM-budgeted tile per
# input shape at solve time.
RGB_DEFAULT_TILE = 32

_DTYPE_ITEMSIZE = {"float32": 4, "float64": 8}


def jnp_itemsize(dtype: str) -> int:
    return _DTYPE_ITEMSIZE[dtype]


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Full configuration of a batch 2-D LP solve.

    Parameters
    ----------
    backend:
        ``"naive"`` (divergence-emulating vmap baseline), ``"rgb"``
        (pure-JAX cooperative tiles), ``"kernel"`` (Pallas TPU kernel),
        ``"pdhg"`` (restarted first-order solver, :mod:`repro.pdhg` —
        matrix-free, scales past small m, answers to a tolerance) or
        ``"auto"`` (the fastest *measured* backend for the input shape
        when the tuning table has entries, else kernel on TPU / rgb
        elsewhere — resolved by :meth:`resolve`/:meth:`build` and
        :meth:`resolve_for_shape`).
    tile:
        problems per cooperative tile.  ``None`` means "pick per
        shape": the measured tuning table when it has an entry,
        otherwise the backend default (32 for ``rgb``, a VMEM-budgeted
        choice for ``kernel``); ignored by ``naive``.
    chunk:
        lane-chunk size for the chunked O(i) re-solve.  ``None`` means
        "pick per shape" (table, then the dense default); ``0``
        explicitly requests the dense re-solve.
    M:
        box bound on both coordinates (must not bind at the optimum).
    normalize:
        scale every constraint to unit norm before solving (keeps every
        epsilon an absolute distance; strongly recommended).
    shuffle:
        apply Seidel's randomised constraint order on every solve,
        keyed by ``seed`` unless a per-call key is given.
    seed:
        key for ``shuffle=True`` when no per-call key overrides it.
    interpret:
        ``kernel`` backend only — run the Pallas kernel body in
        interpret mode.  ``None`` resolves to True on a CPU backend so
        the kernel stays runnable in tests/CI.
    dtype:
        solve precision, ``"float32"`` or ``"float64"`` (inputs are
        cast on entry).
    iter_block:
        ``pdhg`` only — iterations fused per ``lax.while_loop`` block
        (residuals/restarts are checked at block boundaries).  ``None``
        means "pick per shape": tuning table, then the pdhg default.
    restart_period:
        ``pdhg`` only — artificial restart period in iterations (``0``
        disables the periodic trigger, adaptive restarts still fire).
        ``None`` resolves like ``iter_block``.
    tol:
        ``pdhg`` only — relative KKT tolerance; ``None`` picks the
        dtype default (1e-4 float32, 1e-8 float64).
    max_iters:
        ``pdhg`` only — iteration budget; ``None`` picks the dtype
        default (20k float32, 100k float64).
    """

    backend: str = "auto"
    tile: Optional[int] = None
    chunk: Optional[int] = None
    M: float = DEFAULT_M
    normalize: bool = True
    shuffle: bool = False
    seed: int = 0
    interpret: Optional[bool] = None
    dtype: str = "float32"
    iter_block: Optional[int] = None
    restart_period: Optional[int] = None
    tol: Optional[float] = None
    max_iters: Optional[int] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of "
                f"{BACKENDS}")
        if self.tile is not None and (not isinstance(self.tile, int)
                                      or self.tile < 1):
            raise ValueError(f"tile={self.tile!r} must be a positive int "
                             "or None")
        if self.chunk is not None and (not isinstance(self.chunk, int)
                                       or self.chunk < 0):
            raise ValueError(f"chunk={self.chunk!r} must be an int >= 0 "
                             "or None")
        M = float(self.M)
        if not M > 0.0:
            raise ValueError(f"M={self.M!r} must be > 0")
        object.__setattr__(self, "M", M)
        if not isinstance(self.seed, int):
            raise ValueError(f"seed={self.seed!r} must be an int")
        dt = str(self.dtype)
        if dt not in DTYPES:
            raise ValueError(f"dtype={self.dtype!r}; expected one of "
                             f"{DTYPES}")
        object.__setattr__(self, "dtype", dt)
        if self.iter_block is not None and (
                not isinstance(self.iter_block, int)
                or self.iter_block < 1):
            raise ValueError(f"iter_block={self.iter_block!r} must be a "
                             "positive int or None")
        if self.restart_period is not None and (
                not isinstance(self.restart_period, int)
                or self.restart_period < 0):
            raise ValueError(f"restart_period={self.restart_period!r} "
                             "must be an int >= 0 or None (0 disables "
                             "the periodic trigger)")
        if self.tol is not None:
            tol = float(self.tol)
            if not tol > 0.0:
                raise ValueError(f"tol={self.tol!r} must be > 0 or None")
            object.__setattr__(self, "tol", tol)
        if self.max_iters is not None and (
                not isinstance(self.max_iters, int)
                or self.max_iters < 1):
            raise ValueError(f"max_iters={self.max_iters!r} must be a "
                             "positive int or None")
        if self.backend != "pdhg":
            stray = [f for f in PDHG_ONLY_FIELDS
                     if getattr(self, f) is not None]
            if stray:
                raise ValueError(
                    f"{', '.join(stray)} are pdhg-only knobs; "
                    f"backend={self.backend!r} does not interpret them "
                    "(build a SolverSpec(backend='pdhg', ...) instead)")

    # -- resolution ------------------------------------------------------

    @property
    def is_resolved(self) -> bool:
        """True once ``backend`` and ``interpret`` are concrete."""
        return self.backend != "auto" and self.interpret is not None

    def resolve(self, platform: Optional[str] = None) -> "SolverSpec":
        """Pin ``"auto"`` choices against the running JAX backend and
        canonicalise inert fields.

        Environment-dependent choices (``backend="auto"``,
        ``interpret=None``) become concrete; fields that cannot affect
        execution are pinned (``interpret`` off the kernel backend,
        ``seed`` when ``shuffle=False``), so specs with identical
        execution plans resolve equal and share executable-cache
        entries.  Unset launch geometry (``tile=None``/``chunk=None``)
        stays the sentinel — it means "pick per shape" and is pinned by
        :meth:`resolve_for_shape` where the input shape is known.
        """
        platform = platform or jax.default_backend()
        if self.dtype == "float64" and not jax.config.jax_enable_x64:
            raise ValueError(
                "dtype='float64' needs jax x64 enabled (set "
                "jax_enable_x64=True or JAX_ENABLE_X64=1); refusing to "
                "silently truncate the solve to float32")
        backend = self.backend
        if backend == "auto":
            backend = "kernel" if platform == "tpu" else "rgb"
        if backend == "kernel":
            interpret = (platform == "cpu" if self.interpret is None
                         else bool(self.interpret))
        else:
            interpret = False
        seed = self.seed if self.shuffle else 0
        if (backend == self.backend and interpret == self.interpret
                and seed == self.seed):
            return self
        return dataclasses.replace(self, backend=backend,
                                   interpret=interpret, seed=seed)

    @property
    def is_shape_resolved(self) -> bool:
        """True once launch geometry is concrete as well (for ``pdhg``
        that includes the block/restart schedule)."""
        if self.backend == "pdhg" and (self.iter_block is None
                                       or self.restart_period is None):
            return False
        return (self.is_resolved and self.tile is not None
                and self.chunk is not None)

    def resolve_for_shape(self, m: int, batch: Optional[int] = None,
                          platform: Optional[str] = None) -> "SolverSpec":
        """Fully pin the spec for one input shape: environment choices
        via :meth:`resolve`, then launch geometry with the precedence
        **explicit > tuning table > heuristic**.

        ``m`` is the (padded) constraint count of the batch, ``batch``
        its problem count (``None`` if unknown — table lookups then use
        the batch-wildcard rung).  For ``backend="auto"`` the measured
        table may also pick the backend: the fastest recorded backend
        at this shape wins over the platform default when measurements
        exist.  A table miss — or the table being unavailable for any
        reason — falls back to today's static heuristics; this method
        never raises on tuning problems.
        """
        from repro.kernels.batch_lp import LANE, _pick_tile  # deferred
        try:
            from repro.tune.table import active_table
            table = active_table()
        except Exception:   # tuning must never take the solver down
            table = None
        spec = self
        if spec.backend == "auto" and table is not None:
            try:
                best = table.lookup_best_backend(dtype=spec.dtype, m=m,
                                                 batch=batch)
            except Exception:
                best = None
            if best is not None:
                spec = dataclasses.replace(
                    spec, backend=best.key.backend)
        spec = spec.resolve(platform)
        if spec.is_shape_resolved:
            return spec
        if spec.backend == "pdhg":
            return spec._resolve_pdhg_shape(table, m, batch)
        tile, chunk = spec.tile, spec.chunk
        entry = None
        if table is not None and (tile is None or chunk is None):
            try:
                entry = table.lookup(backend=spec.backend,
                                     dtype=spec.dtype, m=m, batch=batch)
            except Exception:
                entry = None
        if entry is not None:
            if tile is None:
                tile = entry.tile
            if chunk is None:
                chunk = entry.chunk
        # Heuristic floor: exactly the pre-tuning behaviour.
        m_lane = -(-m // LANE) * LANE
        if tile is None:
            if spec.backend == "kernel":
                tile = _pick_tile(m_lane, batch,
                                  itemsize=jnp_itemsize(spec.dtype))
            else:
                tile = RGB_DEFAULT_TILE
        chunk_from_table = chunk is not None and spec.chunk is None
        if chunk is None:
            chunk = 0
        if (spec.backend == "kernel" and chunk and chunk_from_table
                and m_lane % chunk):
            # A bucketed table entry can carry a chunk that does not
            # divide this shape's lane-rounded m; run dense instead of
            # letting rgb_pallas reject the launch.  (An *explicit*
            # invalid chunk still fails loudly there, as before.)
            chunk = 0
        if tile == spec.tile and chunk == spec.chunk:
            return spec
        return dataclasses.replace(spec, tile=tile, chunk=chunk)

    def _resolve_pdhg_shape(self, table, m: int,
                            batch: Optional[int]) -> "SolverSpec":
        """Pin the pdhg schedule (same precedence as tile/chunk).  A
        pdhg table entry's two geometry slots carry ``(iter_block,
        restart_period)`` — see :mod:`repro.tune.table`.  ``tile`` and
        ``chunk`` are inert for pdhg but still pinned to concrete
        values so shape-resolved consumers (the serving layer's
        ``ExecSpec`` batch ladder) keep working unchanged."""
        from repro.pdhg import (DEFAULT_ITER_BLOCK,
                                DEFAULT_RESTART_PERIOD)  # deferred
        ib, rp = self.iter_block, self.restart_period
        if table is not None and (ib is None or rp is None):
            try:
                entry = table.lookup(backend="pdhg", dtype=self.dtype,
                                     m=m, batch=batch)
            except Exception:
                entry = None
            if entry is not None:
                if ib is None:
                    ib = entry.tile
                if rp is None:
                    rp = entry.chunk
        if ib is None:
            ib = DEFAULT_ITER_BLOCK
        if rp is None:
            rp = DEFAULT_RESTART_PERIOD
        tile = self.tile if self.tile is not None else RGB_DEFAULT_TILE
        chunk = self.chunk if self.chunk is not None else 0
        if (ib == self.iter_block and rp == self.restart_period
                and tile == self.tile and chunk == self.chunk):
            return self
        return dataclasses.replace(self, iter_block=ib,
                                   restart_period=rp, tile=tile,
                                   chunk=chunk)

    # -- construction of the runtime object ------------------------------

    def build(self) -> "Solver":
        """Resolve and wrap into a reusable :class:`Solver` (fresh
        instance; use :func:`get_solver` for a process-wide cached
        one)."""
        from repro.solver.solver import Solver  # deferred: import cycle
        return Solver(self)


@functools.lru_cache(maxsize=None)
def _cached_solver(spec: SolverSpec) -> "Solver":
    from repro.solver.solver import Solver  # deferred: import cycle
    return Solver(spec)


def get_solver(spec: SolverSpec) -> "Solver":
    """Process-wide ``spec -> Solver`` cache.

    Equal specs share one Solver — and therefore one per-shape compile
    cache — which keeps sweeps like
    ``[get_solver(s).solve(batch) for s in sweep]`` cheap to re-run.
    """
    return _cached_solver(spec.resolve())
