"""AdamW with framework integrations.

* Optimizer state (m, v, fp32 master copy optional) inherits each
  parameter's sharding, so with FSDP-sharded params the state is ZeRO-
  sharded automatically — the update is purely local and elementwise.
* ``sync_duplicated_grads`` averages gradients across the KV-head copies
  that TP replication introduced (models.transformer.init_attn_params tiles
  them identically at init; averaging keeps them identical forever, which
  keeps the padded layout exactly equal to the real GQA architecture).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g, state.m, grads)
        new_v = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
            state.v, grads)

        def upd(p, m, v):
            mh = m / b1c
            vh = v / b2c
            u = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-self.lr * u).astype(jnp.float32)

        updates = jax.tree.map(upd, params, new_m, new_v)
        return updates, AdamWState(step=step, m=new_m, v=new_v)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ---------------------------------------------------------------------------
# Duplicated-KV gradient averaging
# ---------------------------------------------------------------------------

def sync_duplicated_grads(grads, dup_map: Dict[str, int], hd: int):
    """dup_map: slash-path -> replication factor.  The duplicated axis is
    always the trailing (kv_total*hd) weight column / bias axis laid out
    head-major, so averaging is reshape (..., n_kv, rep, hd) -> mean."""
    if not dup_map:
        return grads
    flat = _flatten_with_paths(grads)
    for path, rep in dup_map.items():
        if path not in flat:
            continue
        g = flat[path]
        last = g.shape[-1]
        n_kv = last // (rep * hd)
        gr = g.reshape(g.shape[:-1] + (n_kv, rep, hd))
        gr = jnp.broadcast_to(gr.mean(axis=-2, keepdims=True), gr.shape)
        flat[path] = gr.reshape(g.shape)
    return _unflatten_with_paths(flat, grads)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    out = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}" if prefix else k, v)
        else:
            out[prefix] = node

    rec("", tree)
    return out


def _unflatten_with_paths(flat: Dict[str, Any], like):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        return flat[prefix]

    return rec("", like)
