from repro.optim.adamw import (AdamW, AdamWState, apply_updates,
                               global_norm, sync_duplicated_grads)
from repro.optim.compress import (compressed_psum, dequantize_int8,
                                  init_error_state, quantize_int8)
from repro.optim.lp_clip import lp_constrain_updates

__all__ = ["AdamW", "AdamWState", "apply_updates", "global_norm",
           "sync_duplicated_grads", "compressed_psum", "dequantize_int8",
           "init_error_state", "quantize_int8", "lp_constrain_updates"]
