"""LP-constrained update scaling: the paper's batch 2-D LP solver as a
first-class training feature.

For every parameter block we pose a tiny 2-D linear program over
(s1 = proposed-update scale, s2 = momentum-correction scale):

    maximize    s1 + lambda * s2
    subject to  s1 * ||u||    <= delta * (||p|| + eps)   (trust region)
                s1 * <u, g> + s2 * <mu, g> <= 0          (descent guard)
                0 <= s1 <= 1,   -1 <= s2 <= 1            (box)

where u is the optimizer's proposed update, g the gradient and mu the unit
momentum direction.  One LP per parameter block -> a *batch* of LPs with
identical structure but different coefficients — exactly the workload
shape the paper accelerates — solved on-device through a
repro.solver.SolverSpec (the Pallas kernel backend on TPU).

This is deliberately lightweight (a handful of constraints per LP); its
purpose is to exercise the paper's solver inside the training loop and to
give a principled per-block trust region, not to be a new optimizer.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.lp import make_batch
from repro.solver import SolverSpec, get_solver

_EPS = 1e-12


def _block_stats(u, g, m):
    u32 = u.astype(jnp.float32).ravel()
    g32 = g.astype(jnp.float32).ravel()
    m32 = m.astype(jnp.float32).ravel()
    un = jnp.linalg.norm(u32)
    mn = jnp.linalg.norm(m32)
    mu = m32 / (mn + _EPS)
    return un, jnp.dot(u32, g32), jnp.dot(mu, g32)


def lp_constrain_updates(
    updates, grads, momenta, params,
    *,
    delta: float = 0.05,
    lam: float = 0.1,
    method: str = "rgb",
) -> Tuple[Any, jax.Array]:
    """Scale each update leaf by the LP-optimal (s1, s2).

    Returns (new_updates, mean_s1) — mean_s1 is a health metric: 1.0 means
    the trust region never binds.
    """
    leaves_u, tdef = jax.tree.flatten(updates)
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(momenta)
    leaves_p = jax.tree.leaves(params)
    nb = len(leaves_u)

    rows = []
    for u, g, m, p in zip(leaves_u, leaves_g, leaves_m, leaves_p):
        un, ug, mg = _block_stats(u, g, m)
        pn = jnp.linalg.norm(p.astype(jnp.float32).ravel())
        # the s2 momentum correction is scaled to 10% of the update norm
        mg_s = 0.1 * un * mg
        # constraints (A s <= b), s = (s1, s2)
        A = jnp.stack([
            jnp.stack([un, jnp.zeros(())]),           # s1*||u|| <= d*||p||
            jnp.stack([ug, mg_s]),                    # descent guard <= 0
            jnp.stack([jnp.ones(()), jnp.zeros(())]),   # s1 <= 1
            jnp.stack([-jnp.ones(()), jnp.zeros(())]),  # -s1 <= 0
            jnp.stack([jnp.zeros(()), jnp.ones(())]),   # s2 <= 1
            jnp.stack([jnp.zeros(()), -jnp.ones(())]),  # -s2 <= 1
        ])
        b = jnp.stack([delta * (pn + 1e-3), jnp.zeros(()), jnp.ones(()),
                       jnp.zeros(()), jnp.ones(()), jnp.ones(())])
        rows.append((A, b))

    A = jnp.stack([r[0] for r in rows])  # (nb, 6, 2)
    b = jnp.stack([r[1] for r in rows])  # (nb, 6)
    c = jnp.broadcast_to(jnp.asarray([1.0, lam], jnp.float32), (nb, 2))
    # __call__ is the composable path: lp_constrain_updates runs inside
    # the caller's jitted train step.
    sol = get_solver(SolverSpec(backend=method, M=10.0))(
        make_batch(A, b, c))
    s1 = jnp.where(sol.feasible, sol.x[:, 0], 1.0)
    s2 = jnp.where(sol.feasible, sol.x[:, 1], 0.0)

    new_leaves = []
    for i, (u, m) in enumerate(zip(leaves_u, leaves_m)):
        u32 = u.astype(jnp.float32)
        mn = jnp.linalg.norm(m.astype(jnp.float32).ravel()) + _EPS
        un = jnp.linalg.norm(u32.ravel())
        nu = (s1[i] * u32
              + 0.1 * un * s2[i] * m.astype(jnp.float32) / mn)
        new_leaves.append(nu.astype(u.dtype))
    return jax.tree.unflatten(tdef, new_leaves), jnp.mean(s1)
