"""Error-feedback int8 gradient compression for cross-pod all-reduce.

Cross-pod ICI/DCN links are the scarcest bandwidth in a multi-pod mesh, so
the pod-axis gradient reduction is the natural place to compress.  We use
the classic error-feedback scheme (1-bit Adam / EF-SGD lineage):

    e      <- residual carried from the last step
    q      = quantize(g + e)          # int8, per-tensor scale
    e'     = (g + e) - dequant(q)     # quantization error, fed back
    g_out  = psum(q, 'pod') * scale   # 4x fewer bytes on the wire

Error feedback makes the *accumulated* quantization error bounded, so
convergence matches uncompressed SGD/Adam to first order (Karimireddy et
al., 2019).  Used by launch.steps.make_train_step(manual_comm=True); the
int8 psum over the pod axis is visible in the dry-run HLO as an
all-reduce on s8 operands, which is how the roofline credits the 4x.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, error_state, axis_name: str, axis_size: int):
    """Error-feedback compressed all-reduce of a gradient pytree over
    ``axis_name``.  Scales are reduced with pmax so every pod dequantizes
    identically.  Returns (reduced_grads, new_error_state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        # shared scale across the axis so the integer sum is coherent
        amax = lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale
        # int8 payload on the wire; accumulate in int32 to avoid overflow
        summed = lax.psum(q.astype(jnp.int32), axis_name)
        return (summed.astype(jnp.float32) * scale / axis_size), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree.unflatten(tdef, [o[0] for o in out])
    err = jax.tree.unflatten(tdef, [o[1] for o in out])
    return red, err
