"""Observability-layer tests: repro.obs end to end.

Span plumbing is exercised against a *real* traced
:class:`BatchScheduler` (parent links, ordering invariants, fused
multi-bucket flush membership), the trace context rides a *real*
socket round-trip through the RPC front end, and the flight recorder
is triggered by an *injected* flush failure — not by calling
``trigger`` by hand.  Pure-structure pieces (ring wraparound, Chrome
trace schema, histogram exposition grammar, the JSON log formatter,
the snapshot race) are unit-tested directly.
"""
import io
import json
import logging
import threading
import time

import numpy as np
import pytest

from repro.obs import (FlightRecorder, NOOP_TRACER, SpanBuffer, Tracer,
                       check_span_chains, current_context, device_idle,
                       new_trace_context, parse_trace_header,
                       setup_logging, to_chrome_trace, use_context)
from repro.obs.export import validate_chrome_trace
from repro.obs.log import JsonFormatter, TextFormatter
from repro.obs.trace import (Span, flush_membership, span_index,
                             spans_for_trace)
from repro.serve_lp import BatchScheduler, ExecutableCache, SolverSpec
from repro.serve_lp.metrics import ServeMetrics
from repro.serve_lp.rpc import (make_frontend, render_metrics,
                                validate_exposition)
from repro.serve_lp.rpc.server import run_in_thread

SPEC = SolverSpec(backend="rgb", tile=16, chunk=0)


def _lp(seed=0, m=8):
    rng = np.random.default_rng(seed)
    xstar = rng.uniform(-10, 10, 2)
    theta = rng.uniform(0, 2 * np.pi, m)
    A = np.stack([np.cos(theta), np.sin(theta)], -1).astype(np.float32)
    b = (A @ xstar + rng.uniform(0.1, 3.0, m)).astype(np.float32)
    phi = rng.uniform(0, 2 * np.pi)
    c = np.array([np.cos(phi), np.sin(phi)], np.float32)
    return A, b, c


# -- trace context / header ------------------------------------------------

def test_parse_trace_header():
    ctx = new_trace_context()
    # bare trace id: parsed, fresh span id
    got = parse_trace_header(ctx.trace_id)
    assert got is not None and got.trace_id == ctx.trace_id
    # full "trace-span" form round-trips exactly
    got = parse_trace_header(ctx.header_value())
    assert (got.trace_id, got.span_id) == (ctx.trace_id, ctx.span_id)
    # malformed values are None, never an exception
    for bad in (None, "", "xyz", "0" * 31, "0" * 33,
                "0" * 32 + "-zz", "0" * 32 + "-" + "0" * 15,
                "0" * 32 + "-" + "0" * 16 + "-extra"):
        assert parse_trace_header(bad) is None, bad


def test_ring_wraparound():
    ring = SpanBuffer(capacity=4)
    for i in range(10):
        ring.append(Span("t" * 32, f"{i:016x}", None, "x",
                         t_start=float(i), t_end=float(i) + 0.5))
    assert len(ring) == 4
    assert ring.total == 10
    assert ring.dropped == 6
    snap = ring.snapshot()
    # oldest first, and only the newest 4 survive
    assert [s.t_start for s in snap] == [6.0, 7.0, 8.0, 9.0]
    ring.clear()
    assert len(ring) == 0 and ring.snapshot() == []


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    s = tr.start_span("request", "a" * 32)
    assert s is None
    tr.end(s)                      # None accepted, no branching needed
    assert tr.record("device.solve", "a" * 32, None, 0.0, 1.0) is None
    assert tr.stats()["spans_recorded"] == 0
    assert tr.stats()["noop_calls"] == 3
    assert NOOP_TRACER.enabled is False


# -- scheduler span chains -------------------------------------------------

def test_scheduler_span_chain_invariants():
    tracer = Tracer(enabled=True)
    with BatchScheduler(SPEC, max_batch=4, max_wait_s=0.002,
                        tracer=tracer) as sched:
        futs = [sched.submit(*_lp(i)) for i in range(8)]
        for f in futs:
            assert f.result(timeout=60.0).feasible
    spans = tracer.spans()
    report = check_span_chains(spans)
    assert report["complete"] == 8
    assert report["problems"] == []
    by_id = span_index(spans)
    for s in spans:
        if s.name == "queue.wait":
            parent = by_id[s.parent_id]
            assert parent.name == "request"
            assert parent.trace_id == s.trace_id
            assert s.t_start >= parent.t_start
    # flush-plane spans all carry the flush label and a device track
    names = {s.name for s in spans}
    assert {"flush.assemble", "flush.dispatch", "device.solve",
            "flush.scatter"} <= names
    for s in spans:
        if s.name.startswith("flush.") or s.name == "device.solve":
            assert s.attrs.get("flush")
    idle = device_idle(spans)
    assert idle["window_s"] > 0.0
    assert 0.0 <= idle["idle_frac"] <= 1.0


def test_fused_flush_membership_routes_all_traces():
    tracer = Tracer(enabled=True)
    with BatchScheduler(SPEC, max_batch=64, max_wait_s=10.0,
                        tracer=tracer) as sched:
        # two m-buckets, both underfull -> one fused flush on close
        futs = ([sched.submit(*_lp(i, m=8)) for i in range(3)]
                + [sched.submit(*_lp(100 + i, m=64)) for i in range(3)])
        sched.flush()
        for f in futs:
            f.result(timeout=60.0)
    spans = tracer.spans()
    members = flush_membership(spans)
    fused = [name for name, tids in members.items() if len(tids) == 6]
    assert fused, f"no flush held all 6 traces: {members}"
    asm = next(s for s in spans if s.name == "flush.assemble"
               and s.attrs["flush"] == fused[0])
    assert asm.attrs["n_buckets"] >= 2
    # every member trace can pull the shared flush plane
    for tid in members[fused[0]]:
        mine = spans_for_trace(spans, tid)
        names = {s.name for s in mine}
        assert {"request", "queue.wait", "flush.assemble",
                "flush.dispatch", "device.solve",
                "flush.scatter"} <= names


def test_untraced_scheduler_records_nothing():
    with BatchScheduler(SPEC, max_batch=4, max_wait_s=0.002) as sched:
        futs = [sched.submit(*_lp(i)) for i in range(4)]
        for f in futs:
            f.result(timeout=60.0)
        stats = sched.tracer.stats()
    assert stats["enabled"] == 0
    assert stats["spans_recorded"] == 0
    assert stats["spans_started"] == 0


# -- RPC round-trip --------------------------------------------------------

def test_trace_id_roundtrip_over_socket():
    import http.client
    tracer = Tracer(enabled=True)
    f = make_frontend(SPEC, max_batch=4, max_wait_s=0.003,
                      tracer=tracer)
    port, stop = run_in_thread(f)
    try:
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        A, b, c = _lp()
        body = json.dumps({"A": A.tolist(), "b": b.tolist(),
                           "c": c.tolist()})
        tid = "ab" * 16
        conn.request("POST", "/v1/solve", body,
                     {"X-Trace-Id": tid, "X-Deadline-Ms": "60000"})
        resp = conn.getresponse()
        assert resp.status == 200
        assert resp.getheader("X-Trace-Id") == tid
        resp.read()
        # absent header: the server mints one and echoes it
        conn.request("POST", "/v1/solve", body,
                     {"X-Deadline-Ms": "60000"})
        resp = conn.getresponse()
        minted = resp.getheader("X-Trace-Id")
        resp.read()
        assert minted and len(minted) == 32 and minted != tid
        # the trace is pullable as Chrome JSON scoped to the id
        conn.request("GET", f"/debug/trace?trace_id={tid}")
        resp = conn.getresponse()
        assert resp.status == 200
        obj = json.loads(resp.read())
        validate_chrome_trace(obj)
        assert obj["traceEvents"]
        # and as raw spans: rpc.handle -> admit/request parentage
        conn.request("GET", f"/debug/trace?trace_id={tid}&format=spans")
        sp = json.loads(conn.getresponse().read())["spans"]
        by_name = {}
        for s in sp:
            by_name.setdefault(s["name"], []).append(s)
        handle = by_name["rpc.handle"][0]
        assert by_name["admit"][0]["parent_id"] == handle["span_id"]
        assert by_name["request"][0]["parent_id"] == handle["span_id"]
        conn.close()
    finally:
        stop()
    chains = check_span_chains(tracer.spans())
    assert chains["problems"] == []


# -- flight recorder -------------------------------------------------------

class _BadExe:
    def dispatch(self, L, c, mv):
        return None

    def complete(self, handle):
        raise RuntimeError("injected device failure")


def test_flight_recorder_triggers_on_flush_failure(tmp_path):
    tracer = Tracer(enabled=True)
    rec = FlightRecorder(str(tmp_path), tracer=tracer,
                         min_interval_s=0.0)
    sched = BatchScheduler(SPEC, max_batch=4, max_wait_s=0.002,
                           tracer=tracer, recorder=rec)
    sched.cache = ExecutableCache(lambda spec: _BadExe())
    with sched:
        futs = [sched.submit(*_lp(i)) for i in range(4)]
        for f in futs:
            with pytest.raises(RuntimeError):
                f.result(timeout=60.0)
    assert rec.stats()["written"] >= 1
    names = rec.list_snapshots()
    assert names
    snap = rec.load_snapshot(names[0])
    assert snap["schema"] == "repro.obs.flight/1"
    assert snap["reason"].startswith("error:")
    assert snap["scheduler"]["n_devices"] >= 1
    assert any(s["name"] == "request" for s in snap["spans"])


def test_flight_recorder_debounce_prune_and_safety(tmp_path):
    rec = FlightRecorder(str(tmp_path), min_interval_s=3600.0,
                         max_snapshots=2)
    assert rec.trigger("one") is not None
    assert rec.trigger("two") is None          # debounced
    assert rec.stats()["suppressed"] == 1
    rec._t_last_write = -1e9                   # bypass debounce
    rec.trigger("two")
    rec._t_last_write = -1e9
    rec.trigger("three")
    assert len(rec.list_snapshots()) == 2      # pruned to max_snapshots
    assert rec.load_snapshot("../etc/passwd") is None
    assert rec.load_snapshot("nope.json") is None


def test_flight_recorder_p99_gate(tmp_path):
    rec = FlightRecorder(str(tmp_path), p99_threshold_s=0.1,
                         min_interval_s=0.0)
    rec.check_p99(0.05)
    assert rec.stats()["written"] == 0
    rec.check_p99(0.5)
    assert rec.stats()["written"] == 1
    assert "p99_threshold" in rec.list_snapshots()[0]
    snap = rec.load_snapshot(rec.list_snapshots()[0])
    assert snap["extra"]["p99_s"] == 0.5


# -- exporters -------------------------------------------------------------

def test_chrome_trace_schema():
    tr = Tracer(enabled=True)
    ctx = new_trace_context()
    r = tr.start_span("request", ctx.trace_id, ctx.span_id, bucket_m=8)
    q = tr.start_span("queue.wait", ctx.trace_id, r.span_id)
    tr.end(q)
    tr.end(r)
    tr.record("device.solve", ctx.trace_id, None,
              0.0, 1.0, flush="f1", devices=(0, 1), bucket_m=8)
    obj = to_chrome_trace(tr.spans())
    validate_chrome_trace(obj)
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert "X" in phases and "M" in phases
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"nope": 1})


def test_histogram_exposition_grammar():
    m = ServeMetrics()
    for i in range(40):
        m.record_latency(0.001 * (i + 1), trace_id=f"{i:032x}")
        m.record_queue_wait(0.0005 * (i + 1))
    m.record_flush(bucket_m=8, n_real=4, b_pad=16, sum_m=32,
                   solve_seconds=0.01, assemble_seconds=0.002,
                   reason="size", trace_id="ab" * 16)
    body = render_metrics(m.snapshot(), rpc=None, quotas=None,
                          trace=Tracer(enabled=True).stats())
    validate_exposition(body)
    assert 'le="+Inf"' in body
    assert "request_latency_seconds_bucket" in body
    assert '# {trace_id="' in body       # exemplar on a latency bucket
    assert "repro_serve_trace_enabled 1" in body
    # the validator actually enforces the histogram grammar
    with pytest.raises(ValueError):
        validate_exposition('# TYPE h histogram\nh_bucket{le="1"} 5\n'
                            'h_bucket{le="+Inf"} 3\nh_sum 1\nh_count 3\n')
    with pytest.raises(ValueError):
        validate_exposition('# TYPE h histogram\n'
                            'h_bucket{le="+Inf"} 5\nh_count 5\n')
    with pytest.raises(ValueError):
        validate_exposition('x_bucket{le="1"} 3 # malformed 1.0\n')


def test_snapshot_consistent_under_concurrent_records():
    m = ServeMetrics()
    stop = threading.Event()

    def hammer():
        i = 0
        while not stop.is_set():
            m.record_latency(0.001 * (i % 100 + 1))
            i += 1

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        deadline = time.perf_counter() + 0.5
        while time.perf_counter() < deadline:
            snap = m.snapshot()
            # percentiles come from the same locked copy: ordered and
            # inside the recorded value range
            assert snap["latency_p50_ms"] <= snap["latency_p99_ms"]
            assert 0.0 <= snap["latency_p99_ms"] <= 101.0
            h = snap["histograms"]["request_latency_seconds"]
            assert h["count"] == h["cumulative"][-1]
    finally:
        stop.set()
        for t in threads:
            t.join()


# -- structured logging ----------------------------------------------------

def test_json_log_formatter_binds_trace_context():
    stream = io.StringIO()
    logger = logging.getLogger("repro.test.obs.json")
    logger.propagate = False
    handler = setup_logging(fmt="json", stream=stream, logger=logger)
    try:
        with use_context(trace_id="ab" * 16, tenant="acme"):
            logger.info("flush %d done", 7, extra={"flush": "f-7"})
        logger.warning("outside")
    finally:
        logger.removeHandler(handler)
    lines = [json.loads(l) for l in stream.getvalue().splitlines()]
    assert lines[0]["msg"] == "flush 7 done"
    assert lines[0]["trace_id"] == "ab" * 16
    assert lines[0]["tenant"] == "acme"
    assert lines[0]["flush"] == "f-7"
    assert lines[0]["level"] == "INFO"
    assert "trace_id" not in lines[1]
    assert current_context() == {}


def test_text_formatter_and_setup_validation():
    rec = logging.LogRecord("x", logging.INFO, __file__, 1,
                            "hello", None, None)
    plain = TextFormatter().format(rec)
    assert "hello" in plain and "trace=" not in plain
    with use_context(trace_id="cd" * 16):
        bound = TextFormatter().format(rec)
    assert "trace=" + "cd" * 16 in bound
    with pytest.raises(ValueError):
        setup_logging(fmt="xml")
    # unserializable extras fall back via default=repr, never raise
    out = JsonFormatter().format(
        logging.LogRecord("x", logging.INFO, __file__, 1,
                          "obj %s", (object(),), None))
    assert json.loads(out)["level"] == "INFO"
