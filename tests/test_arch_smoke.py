"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes and no NaNs (the brief's (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, applicable, input_specs, \
    smoke_config
from repro.models import build_model, MeshInfo
from repro.models.common import head_layout

MI1 = MeshInfo(model_size=1, data_size=1)


def make_batch(cfg, B=2, S=32, train=True, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    batch = {}
    s_text = S - cfg.n_prefix if cfg.family == "vlm" else S
    batch["tokens"] = jax.random.randint(ks[0], (B, s_text), 0, cfg.vocab,
                                         jnp.int32)
    if train:
        batch["labels"] = jax.random.randint(ks[1], (B, s_text), 0,
                                             cfg.vocab, jnp.int32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.n_prefix, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[3], (B, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_no_nans(arch):
    cfg = smoke_config(ARCHS[arch])
    model = build_model(cfg, MI1)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert 3.0 < float(metrics["ce"]) < 12.0, \
        f"{arch}: ce {float(metrics['ce'])} outside sane init range"
    g = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill must equal teacher-forced logits."""
    cfg = smoke_config(ARCHS[arch])
    model = build_model(cfg, MI1)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S, train=False)
    logits_pf, cache = jax.jit(model.prefill)(params, batch)
    assert logits_pf.shape[0] == B
    assert np.all(np.isfinite(np.asarray(logits_pf, np.float32)))
    # grow cache and take one decode step
    s_text = batch["tokens"].shape[1]
    grown = jax.tree.map(
        lambda x: (jnp.pad(x, [(0, 0)] * 2 + [(0, 8)] + [(0, 0)] *
                           (x.ndim - 3))
                   if x.ndim >= 3 and x.shape[2] in (S, s_text,
                                                     S + cfg.n_prefix)
                   else x), cache)
    tok = jnp.argmax(logits_pf, axis=-1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S if cfg.family != "vlm" else S, jnp.int32)
    logits_dec, _ = jax.jit(model.decode)(params, {"token": tok,
                                                   "pos": pos}, grown)
    assert np.all(np.isfinite(np.asarray(logits_dec, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_construction(arch):
    """The FULL config is exercised via abstract init only (no alloc)."""
    cfg = ARCHS[arch]
    mi = MeshInfo(model_size=16, data_size=16, data_axes=("data",))
    model = build_model(cfg, mi)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    approx = cfg.param_count()
    # padded-head/vocab layouts may exceed the paper count, never shrink it
    assert n > 0.9 * approx, f"{arch}: {n} vs approx {approx}"
    specs = model.full_param_specs()
    from jax.sharding import PartitionSpec as P

    # every param leaf must have a matching spec whose rank fits and whose
    # model-sharded dims divide evenly
    def check(leaf, spec):
        assert isinstance(spec, P), spec
        entries = tuple(spec)
        assert len(entries) <= leaf.ndim, (leaf.shape, spec)
        for i, ax in enumerate(entries):
            axes = ax if isinstance(ax, tuple) else (ax,)
            if "model" in axes:
                assert leaf.shape[i] % 16 == 0, (leaf.shape, spec, i)
        return leaf

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_complete(arch, shape):
    cfg = ARCHS[arch]
    sh = SHAPES[shape]
    if not applicable(cfg, sh):
        pytest.skip("long_500k on full-attention arch")
    specs = input_specs(cfg, sh)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
    if sh.kind == "decode":
        assert specs["token"].shape == (sh.batch, 1)
    else:
        assert specs["tokens"].shape[0] == sh.batch


def test_head_layouts_all_archs():
    """Layout arithmetic: padded heads cover the real ones for every arch
    at every tp in {1,2,4,8,16}."""
    for arch, cfg in ARCHS.items():
        if cfg.is_attention_free:
            continue
        for tp in (1, 2, 4, 8, 16):
            lay = head_layout(cfg, tp)
            assert lay.h_pad >= cfg.n_heads
            assert lay.h_pad % tp == 0
            assert lay.kv_total % tp == 0
            assert lay.hq_local * tp == lay.h_pad
            assert lay.ql_per_kv * lay.kv_total == lay.h_pad
            # mesh-independence: global padded sizes equal the tp=16 ones
            lay16 = head_layout(cfg, 16)
            assert (lay.h_pad, lay.kv_total) == (lay16.h_pad,
                                                 lay16.kv_total)


def test_train_loss_decreases():
    """A few steps of real training on the smoke config must reduce loss
    (end-to-end integration across data/optim/model)."""
    from repro.launch.train import main as train_main
    loss = train_main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps",
                       "30", "--batch", "8", "--seq", "64",
                       "--log-every", "29"])
    assert loss < 5.2, f"loss {loss} did not decrease from ~5.55 init"
