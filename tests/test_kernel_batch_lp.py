"""Pallas RGB kernel validation: shape/dtype sweeps in interpret mode
against the pure-jnp oracle (kernels.ref) and scipy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (infeasible_lp, normalize_batch, ragged_feasible_lp,
                        random_feasible_lp, shuffle_batch)
from repro.kernels import ops, ref
from repro.kernels.batch_lp import _pick_tile
from repro.solver import SolverSpec, get_solver


def solve_rgb(lp):
    """Reference rgb solve at the historical defaults (tile 32, dense
    re-solve); normalisation already applied by the caller."""
    return get_solver(SolverSpec(backend="rgb", tile=32, chunk=0,
                                 normalize=False)).solve(lp)


def solve_kernel(lp, tile=None):
    """Interpret-mode kernel solve (tile auto unless pinned)."""
    return get_solver(SolverSpec(backend="kernel", tile=tile,
                                 normalize=False,
                                 interpret=True)).solve(lp)


@pytest.mark.parametrize("batch,m", [
    (8, 5), (64, 37), (100, 200), (3, 1), (128, 128), (17, 513),
])
def test_kernel_matches_ref(batch, m):
    lp = random_feasible_lp(jax.random.key(batch + m), batch, m)
    nb = shuffle_batch(jax.random.key(1), normalize_batch(lp))
    r = solve_rgb(nb)
    k = solve_kernel(nb)
    np.testing.assert_array_equal(np.asarray(r.feasible),
                                  np.asarray(k.feasible))
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(k.x),
                               rtol=1e-4, atol=1e-4)


def test_kernel_packed_interface_matches_ref():
    lp = normalize_batch(random_feasible_lp(jax.random.key(0), 32, 50))
    L, c, mv = ops.pack_constraints(lp)
    x_ref, feas_ref = ref.solve_packed_ref(L, c, mv)
    sol = solve_kernel(lp)
    np.testing.assert_allclose(np.asarray(sol.x), np.asarray(x_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(sol.feasible).astype(np.int32), np.asarray(feas_ref))


def test_kernel_infeasible():
    lp = normalize_batch(infeasible_lp(16, 20))
    sol = solve_kernel(lp)
    assert not bool(jnp.any(sol.feasible))


def test_kernel_ragged():
    lp = shuffle_batch(jax.random.key(7), normalize_batch(
        ragged_feasible_lp(jax.random.key(6), 40, 70)))
    r = solve_rgb(lp)
    k = solve_kernel(lp)
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(k.x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tile", [8, 32, 128])
def test_kernel_tile_sizes(tile):
    lp = normalize_batch(random_feasible_lp(jax.random.key(2), 48, 30))
    base = solve_kernel(lp)
    t = solve_kernel(lp, tile=tile)
    np.testing.assert_allclose(np.asarray(base.x), np.asarray(t.x),
                               rtol=1e-5, atol=1e-5)


def test_pick_tile_vmem_budget():
    # The full working set (constraints + c input + x output at the
    # solve dtype, plus int32 mv/feas) must stay within the default 8MB
    # budget at every itemsize
    for itemsize in (4, 8):
        for m_pad in (128, 1024, 8192, 65536):
            t = _pick_tile(m_pad, itemsize=itemsize)
            assert t >= 8 and t % 8 == 0
            working_set = t * ((4 * m_pad + 4) * itemsize + 8)
            assert working_set <= 8 * 1024 * 1024 or t == 8


def test_pick_tile_pinned():
    # Pin chosen tiles for representative (B, m_pad) pairs so VMEM-model
    # changes are deliberate, not accidental.
    assert _pick_tile(128) == 128
    assert _pick_tile(512) == 128
    assert _pick_tile(8192) == 56
    assert _pick_tile(65536) == 8      # floor: minimum viable tile
    # batch clamp: small batches get small tiles (multiple of 8 >= B)
    assert _pick_tile(128, 20) == 24
    assert _pick_tile(128, 4) == 8
    assert _pick_tile(128, 1000) == 128
    assert _pick_tile(8192, 48) == 48
    # float64 working sets are ~2x: tiles shrink instead of overshooting
    # the VMEM budget (the old estimate hardcoded 4-byte elements)
    assert _pick_tile(128, itemsize=8) == 128
    assert _pick_tile(8192, itemsize=8) == 24
    assert _pick_tile(65536, itemsize=8) == 8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), m=st.integers(2, 90),
       batch=st.integers(1, 40))
def test_kernel_property_sweep(seed, m, batch):
    lp = shuffle_batch(jax.random.key(seed + 1), normalize_batch(
        random_feasible_lp(jax.random.key(seed), batch, m)))
    r = solve_rgb(lp)
    k = solve_kernel(lp)
    np.testing.assert_allclose(np.asarray(r.objective),
                               np.asarray(k.objective),
                               rtol=2e-4, atol=2e-4)
