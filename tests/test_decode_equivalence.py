"""Exact decode-path correctness: prefill(prefix) + streamed decode must
produce the SAME logits as prefilling the longer sequence directly.

This pins down every cache mechanism in the framework: KV caches +
position handling (attention archs), conv + SSD state streaming (mamba2),
segment-wise shared-attention caches (zamba2), and self+cross caches
(whisper)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import build_model, MeshInfo

MI1 = MeshInfo(model_size=1, data_size=1)


def _grow_seq_axes(cache, cur: int, new: int):
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == cur:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, new - cur)
            return jnp.pad(x, pad)
        return x
    return jax.tree.map(grow, cache)


@pytest.mark.parametrize("arch", [
    "granite-8b", "qwen2-0.5b", "olmoe-1b-7b", "mamba2-1.3b",
    "zamba2-2.7b", "paligemma-3b", "whisper-base",
])
def test_streamed_decode_matches_prefill(arch):
    cfg = dataclasses.replace(smoke_config(ARCHS[arch]), dtype="float32")
    model = build_model(cfg, MI1)
    params = model.init(jax.random.key(0))
    B, S0, K = 2, 12, 4  # prefill 12 tokens, stream 4 more

    ks = jax.random.split(jax.random.key(1), 3)
    toks = jax.random.randint(ks[0], (B, S0 + K), 0, cfg.vocab, jnp.int32)

    def batch_for(t):
        b = {"tokens": toks[:, :t]}
        if cfg.family == "vlm":
            b["patches"] = jax.random.normal(
                ks[1], (B, cfg.n_prefix, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            b["frames"] = jax.random.normal(
                ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        return b

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)

    # streamed: prefill the prefix, then teacher-forced decode steps
    logits, cache = prefill(params, batch_for(S0))
    cache = _grow_seq_axes(
        cache, S0 + (cfg.n_prefix if cfg.family == "vlm" else 0),
        S0 + K + (cfg.n_prefix if cfg.family == "vlm" else 0))
    stream_logits = [np.asarray(logits)]
    off = cfg.n_prefix if cfg.family == "vlm" else 0
    for t in range(K - 1):
        tok = toks[:, S0 + t][:, None]
        pos = jnp.full((B,), off + S0 + t, jnp.int32)
        logits, cache = decode(params, {"token": tok, "pos": pos}, cache)
        stream_logits.append(np.asarray(logits))

    # reference: full prefill at each length (last-position logits)
    for t in range(K):
        ref_logits, _ = prefill(params, batch_for(S0 + t))
        np.testing.assert_allclose(
            stream_logits[t], np.asarray(ref_logits), rtol=2e-4, atol=2e-4,
            err_msg=f"{arch}: step {t} logits diverge from prefill oracle")
