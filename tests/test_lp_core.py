"""Correctness of the batched Seidel solvers against scipy.linprog and
against each other, plus property-based invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from scipy.optimize import linprog

from repro.core import (adversarial_lp, infeasible_lp, make_batch,
                        normalize_batch, pad_batch, ragged_feasible_lp,
                        random_feasible_lp, replicated_lp, shuffle_batch)
from repro.solver import SolverSpec, get_solver

M_BOX = 1.0e4
RTOL = 3e-4


def solve(lp, method="rgb", key=None, normalize=True):
    """Solve via the unified front end with the historical defaults
    these tests were written against (tile 32, dense re-solve)."""
    spec = SolverSpec(backend=method, tile=32, chunk=0,
                      normalize=normalize)
    return get_solver(spec).solve(lp, key=key)


def scipy_solve(A, b, c):
    r = linprog(-np.asarray(c, np.float64),
                A_ub=np.asarray(A, np.float64),
                b_ub=np.asarray(b, np.float64),
                bounds=[(-M_BOX, M_BOX)] * 2, method="highs")
    return r  # status 0 = optimal, 2 = infeasible


def assert_matches_scipy(batch, sol, rtol=RTOL):
    A = np.asarray(batch.A)
    b = np.asarray(batch.b)
    c = np.asarray(batch.c)
    mv = np.asarray(batch.m_valid)
    for i in range(batch.batch):
        r = scipy_solve(A[i][:mv[i]], b[i][:mv[i]], c[i])
        if r.status == 2:
            assert not bool(sol.feasible[i]), f"problem {i}: scipy says " \
                f"infeasible, solver says feasible"
        else:
            assert r.status == 0, f"scipy status {r.status}"
            assert bool(sol.feasible[i]), f"problem {i}: scipy optimal " \
                f"{-r.fun}, solver says infeasible"
            np.testing.assert_allclose(
                float(sol.objective[i]), -r.fun, rtol=rtol, atol=rtol,
                err_msg=f"problem {i}")


@pytest.mark.parametrize("method", ["naive", "rgb"])
@pytest.mark.parametrize("batch,m", [(32, 8), (16, 100), (5, 3)])
def test_random_feasible_matches_scipy(method, batch, m):
    lp = random_feasible_lp(jax.random.key(batch * m), batch, m)
    sol = solve(lp, method=method, key=jax.random.key(1))
    assert_matches_scipy(lp, sol)


@pytest.mark.parametrize("method", ["naive", "rgb"])
def test_infeasible_detection(method):
    sol = solve(infeasible_lp(8, 12), method=method)
    assert not bool(jnp.any(sol.feasible))


def test_ragged_batch():
    lp = ragged_feasible_lp(jax.random.key(3), 24, 60)
    sol = solve(lp, method="rgb", key=jax.random.key(4))
    assert_matches_scipy(lp, sol)


def test_replicated_batch_identical_results():
    lp = replicated_lp(jax.random.key(5), 16, 40)
    sol = solve(lp, method="rgb")
    x = np.asarray(sol.x)
    np.testing.assert_allclose(x, np.broadcast_to(x[:1], x.shape),
                               rtol=1e-5, atol=1e-5)


def test_adversarial_order_still_correct():
    lp = adversarial_lp(4, 64)
    for key in (None, jax.random.key(0)):
        sol = solve(lp, method="rgb", key=key)
        assert_matches_scipy(lp, sol)


def test_naive_and_rgb_agree():
    lp = random_feasible_lp(jax.random.key(9), 64, 33)
    nb = shuffle_batch(jax.random.key(2), normalize_batch(lp))
    a = solve(nb, method="naive", normalize=False)
    b = solve(nb, method="rgb", normalize=False)
    np.testing.assert_array_equal(np.asarray(a.feasible),
                                  np.asarray(b.feasible))
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x),
                               rtol=1e-5, atol=1e-5)


def test_padding_neutral():
    lp = random_feasible_lp(jax.random.key(11), 8, 17)
    sol1 = solve(lp, method="rgb")
    sol2 = solve(pad_batch(lp, 64), method="rgb")
    np.testing.assert_allclose(np.asarray(sol1.x), np.asarray(sol2.x),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Property-based invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(batch=st.integers(1, 12), m=st.integers(3, 40),
       seed=st.integers(0, 2**30))
def test_solution_is_feasible_and_on_boundary(batch, m, seed):
    """Any reported-feasible solution (a) satisfies all constraints to
    tolerance and (b) either touches a constraint/box boundary or is the
    unconstrained box corner."""
    lp = random_feasible_lp(jax.random.key(seed), batch, m)
    sol = solve(lp, method="rgb", key=jax.random.key(seed + 1))
    A = np.asarray(lp.A, np.float64)
    b = np.asarray(lp.b, np.float64)
    x = np.asarray(sol.x, np.float64)
    feas = np.asarray(sol.feasible)
    nrm = np.linalg.norm(A, axis=-1)
    for i in range(batch):
        if not feas[i]:
            continue
        slack = b[i] - A[i] @ x[i]
        assert (slack / np.maximum(nrm[i], 1e-9) > -1e-2).all(), \
            f"violated constraint, problem {i}: min slack {slack.min()}"


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**30), m=st.integers(3, 30))
def test_shuffle_invariance(seed, m):
    """The optimum must not depend on the (random) consideration order."""
    lp = random_feasible_lp(jax.random.key(seed), 6, m)
    s1 = solve(lp, method="rgb", key=jax.random.key(1))
    s2 = solve(lp, method="rgb", key=jax.random.key(2))
    np.testing.assert_allclose(np.asarray(s1.objective),
                               np.asarray(s2.objective),
                               rtol=5e-4, atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_adding_constraint_never_improves(seed):
    """Monotonicity: the optimum of a superset of constraints is <= the
    optimum of the subset (for maximisation)."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    lp_big = random_feasible_lp(k1, 4, 24)
    lp_small = make_batch(lp_big.A[:, :12], lp_big.b[:, :12], lp_big.c)
    s_small = solve(lp_small, method="rgb", key=k2)
    s_big = solve(lp_big, method="rgb", key=k2)
    ok = ~np.asarray(s_big.feasible) | (
        np.asarray(s_big.objective)
        <= np.asarray(s_small.objective) + 1e-2)
    assert ok.all()


def test_tie_breaking_deterministic():
    """Degenerate objective (c parallel to a constraint edge) still gives
    a unique, deterministic answer."""
    A = np.array([[[0.0, 1.0], [1.0, 0.0]]] * 3)
    b = np.array([[1.0, 1.0]] * 3)
    c = np.array([[0.0, 1.0]] * 3)  # objective parallel to constraint 0
    lp = make_batch(A, b, c)
    s1 = solve(lp, method="rgb")
    s2 = solve(lp, method="naive")
    np.testing.assert_allclose(np.asarray(s1.x), np.asarray(s2.x),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.x[:, 1]), 1.0, rtol=1e-5)
