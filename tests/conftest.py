import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_multidevice(code: str, n_devices: int = 4, timeout: int = 600):
    """Run a python snippet in a subprocess with N forced host devices.

    Tests themselves must see exactly one device (per the project brief),
    so anything needing a real mesh runs out-of-process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"multidevice snippet failed:\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
