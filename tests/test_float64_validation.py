"""float64 path validated against scipy.optimize.linprog.

The solve runs in a subprocess with ``JAX_ENABLE_X64=1`` (x64 must be
set before jax initialises, so it cannot be toggled inside this test
process) over adversarial, ragged and infeasible batches on every
backend; inside, scipy solves the same LPs as
``max c@x  s.t.  A@x <= b, |x|,|y| <= M``.  Skips cleanly when scipy
is unavailable or the jax build cannot enable x64.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parents[1] / "src"

_SNIPPET = r"""
import jax
assert jax.config.jax_enable_x64, "SKIP:x64-unavailable"
try:
    from scipy.optimize import linprog
except Exception:
    raise SystemExit("SKIP:no-scipy")
import numpy as np
from repro.core import adversarial_lp, infeasible_lp, ragged_feasible_lp
from repro.solver import SolverSpec, get_solver

M = 1.0e4
batches = {
    "adversarial": adversarial_lp(4, 24, dtype=jax.numpy.float64),
    "ragged": ragged_feasible_lp(jax.random.key(5), 6, 18, m_min=3,
                                 dtype=jax.numpy.float64),
    "infeasible": infeasible_lp(3, 8, dtype=jax.numpy.float64),
}
specs = {
    "naive": SolverSpec(backend="naive", dtype="float64"),
    "rgb": SolverSpec(backend="rgb", dtype="float64"),
    "rgb-chunked": SolverSpec(backend="rgb", tile=8, chunk=64,
                              dtype="float64"),
    "kernel": SolverSpec(backend="kernel", interpret=True,
                         dtype="float64"),
}
for bname, lp in batches.items():
    A = np.asarray(lp.A); b = np.asarray(lp.b); c = np.asarray(lp.c)
    mv = np.asarray(lp.m_valid)
    ref_obj, ref_feas = [], []
    for i in range(A.shape[0]):
        m = int(mv[i])
        res = linprog(-c[i], A_ub=A[i, :m], b_ub=b[i, :m],
                      bounds=[(-M, M), (-M, M)], method="highs")
        ref_feas.append(res.status == 0)
        ref_obj.append(-res.fun if res.status == 0 else np.nan)
    for sname, spec in specs.items():
        sol = get_solver(spec).solve(lp)
        assert sol.x.dtype == jax.numpy.float64, (bname, sname)
        feas = np.asarray(sol.feasible)
        obj = np.asarray(sol.objective)
        assert list(feas) == ref_feas, (
            f"{bname}/{sname}: feasibility {list(feas)} != scipy "
            f"{ref_feas}")
        for i, ok in enumerate(ref_feas):
            if ok:
                assert abs(obj[i] - ref_obj[i]) <= 1e-7 * (
                    1.0 + abs(ref_obj[i])), (
                    f"{bname}/{sname}[{i}]: objective {obj[i]} != "
                    f"scipy {ref_obj[i]}")
print("float64-validation-ok", len(batches) * len(specs))
"""


_PDHG_SNIPPET = r"""
import jax
assert jax.config.jax_enable_x64, "SKIP:x64-unavailable"
try:
    from scipy.optimize import linprog
except Exception:
    raise SystemExit("SKIP:no-scipy")
import numpy as np
from repro.core import (adversarial_lp, infeasible_lp,
                        ragged_feasible_lp, random_feasible_lp)
from repro.core.packed import pack
from repro.pdhg import solve_pdhg_with_stats
from repro.solver import SolverSpec, get_solver

M = 1.0e4
f64 = jax.numpy.float64

def scipy_ref(lp):
    A = np.asarray(lp.A); b = np.asarray(lp.b); c = np.asarray(lp.c)
    mv = np.asarray(lp.m_valid)
    feas, obj = [], []
    for i in range(A.shape[0]):
        m = int(mv[i])
        res = linprog(-c[i], A_ub=A[i, :m], b_ub=b[i, :m],
                      bounds=[(-M, M), (-M, M)], method="highs")
        feas.append(res.status == 0)
        obj.append(-res.fun if res.status == 0 else np.nan)
    return feas, obj

batches = {
    "adversarial": adversarial_lp(4, 24, dtype=f64),
    "ragged": ragged_feasible_lp(jax.random.key(5), 6, 18, m_min=3,
                                 dtype=f64),
    "infeasible": infeasible_lp(3, 8, dtype=f64),
    "big-m": random_feasible_lp(jax.random.key(11), 4, 2048, dtype=f64),
}
spec = SolverSpec(backend="pdhg", dtype="float64")
for bname, lp in batches.items():
    ref_feas, ref_obj = scipy_ref(lp)
    sol = get_solver(spec).solve(lp)
    assert sol.x.dtype == f64, bname
    feas = np.asarray(sol.feasible); obj = np.asarray(sol.objective)
    assert list(feas) == ref_feas, (
        f"{bname}: feasibility {list(feas)} != scipy {ref_feas}")
    for i, ok in enumerate(ref_feas):
        if ok:
            assert abs(obj[i] - ref_obj[i]) <= 1e-6 * (
                1.0 + abs(ref_obj[i])), (
                f"{bname}[{i}]: objective {obj[i]} != scipy "
                f"{ref_obj[i]}")

# The past-small-m acceptance block: at m=2048 the certificate itself
# must land under 1e-6, not just the objective.
_, st = solve_pdhg_with_stats(pack(batches["big-m"]))
conv = np.asarray(st.converged); pres = np.asarray(st.primal_res)
kkt = np.asarray(st.kkt)
assert conv.all(), f"big-m: {int((~conv).sum())}/4 unconverged {kkt}"
assert (pres <= 1e-6).all(), f"big-m: primal residual {pres}"
assert (kkt <= 1e-6).all(), f"big-m: kkt residual {kkt}"
print("float64-pdhg-ok", len(batches))
"""


def _run_x64_snippet(snippet):
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600)
    tail = (r.stdout + r.stderr)
    if "SKIP:no-scipy" in tail:
        pytest.skip("scipy unavailable in this environment")
    if "SKIP:x64-unavailable" in tail:
        pytest.skip("jax build cannot enable x64")
    return r


def test_float64_matches_scipy():
    r = _run_x64_snippet(_SNIPPET)
    assert r.returncode == 0, (
        f"float64 validation failed:\nSTDOUT:\n{r.stdout}\n"
        f"STDERR:\n{r.stderr}")
    assert "float64-validation-ok" in r.stdout


def test_float64_pdhg_matches_scipy():
    """pdhg f64 vs scipy on the same batch kinds, plus the m=2048
    acceptance block asserting residuals <= 1e-6."""
    r = _run_x64_snippet(_PDHG_SNIPPET)
    assert r.returncode == 0, (
        f"float64 pdhg validation failed:\nSTDOUT:\n{r.stdout}\n"
        f"STDERR:\n{r.stderr}")
    assert "float64-pdhg-ok" in r.stdout
