"""Optional-import shim for ``hypothesis``.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  When hypothesis is installed the real decorators
are re-exported unchanged; when it is missing the property tests still run,
degraded to a small deterministic sample sweep (seeded by the test name) so
the suite stays green — and still exercises the code under test — in the
minimal container.
"""
from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random

    _MAX_FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def settings(**kw):
        max_examples = kw.get("max_examples", _MAX_FALLBACK_EXAMPLES)

        def deco(fn):
            fn._shim_max_examples = min(max_examples,
                                        _MAX_FALLBACK_EXAMPLES)
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: pytest must see a zero-arg signature,
            # otherwise the strategy params look like missing fixtures.
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples",
                            _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
