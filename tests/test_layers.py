"""Unit tests for model layers: attention equivalences, SSD scan vs
sequential recurrence, MoE routing invariants, RoPE, losses."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models import layers as L
from repro.models.common import MeshInfo, ModelConfig

MI1 = MeshInfo(model_size=1, data_size=1)


def test_flash_equals_dense_all_masks():
    q = jax.random.normal(jax.random.key(1), (2, 128, 2, 3, 16))
    k = jax.random.normal(jax.random.key(2), (2, 128, 2, 16))
    v = jax.random.normal(jax.random.key(3), (2, 128, 2, 16))
    for mm in ("causal", "full", "prefix"):
        a = L.dense_attention(q, k, v, mask_mode=mm, prefix=5)
        b = L.flash_attention(q, k, v, mask_mode=mm, prefix=5,
                              chunk_q=32, chunk_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_decode_attention_equals_dense_last_row():
    """Decoding position t must equal row t of dense causal attention."""
    B, S, G, Qg, D = 2, 24, 1, 2, 8
    q = jax.random.normal(jax.random.key(1), (B, S, G, Qg, D))
    k = jax.random.normal(jax.random.key(2), (B, S, G, D))
    v = jax.random.normal(jax.random.key(3), (B, S, G, D))
    dense = L.dense_attention(q, k, v, mask_mode="causal")
    t = S - 1
    out = L.decode_attention(q[:, t:t + 1], k, v,
                             jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(dense[:, t]),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunked_matches_sequential():
    """The chunked SSD algorithm == the naive per-token recurrence."""
    B, S, H, P, N = 2, 64, 3, 8, 16
    ks = jax.random.split(jax.random.key(0), 5)
    xs = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))

    for chunk in (8, 16, 64):
        y, state = L.ssd_chunked(xs, dt, A, Bc, Cc, chunk)
        # sequential oracle
        st_ref = jnp.zeros((B, H, N, P))
        ys = []
        for t in range(S):
            st_ref, yt = L.ssd_decode_step(
                st_ref, xs[:, t], dt[:, t], A, Bc[:, t], Cc[:, t])
            ys.append(yt)
        y_ref = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(state), np.asarray(st_ref),
                                   rtol=2e-3, atol=2e-3)


def test_causal_conv_streaming_matches_batch():
    B, S, C, K = 2, 16, 6, 4
    x = jax.random.normal(jax.random.key(0), (B, S, C))
    w = jax.random.normal(jax.random.key(1), (K, C))
    y_full, _ = L._causal_conv(x, w)
    cache = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(S):
        yt, cache = L._causal_conv(x[:, t:t + 1], w, cache)
        outs.append(yt)
    y_stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_stream),
                               rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm_and_relativity():
    B, S, H, D = 2, 32, 2, 16
    x = jax.random.normal(jax.random.key(0), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_tables(pos, D, 1e4, jnp.float32)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, D))
    v = jax.random.normal(jax.random.key(2), (1, 1, 1, D))
    def dot_at(p, k):
        pos1 = jnp.full((1, 1), p)
        pos2 = jnp.full((1, 1), p + k)
        c1, s1 = L.rope_tables(pos1, D, 1e4, jnp.float32)
        c2, s2 = L.rope_tables(pos2, D, 1e4, jnp.float32)
        return float(jnp.sum(L.apply_rope(q, c1, s1) *
                             L.apply_rope(v, c2, s2)))
    assert abs(dot_at(3, 5) - dot_at(11, 5)) < 1e-4


def _moe_cfg(E=8, k=2):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv=2, d_ff=32, vocab=64, n_experts=E,
                       top_k=k)


def test_moe_capacity_and_combination():
    cfg = _moe_cfg()
    ks = jax.random.split(jax.random.key(0), 5)
    B, S, d, E, f = 2, 16, 16, 8, 32
    p = {
        "w_router": jax.random.normal(ks[0], (d, E)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, d, f)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, d, f)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, f, d)) * 0.1,
    }
    x = jax.random.normal(ks[4], (B, S, d))
    y, aux = L.moe_layer(p, x, MI1, cfg, capacity_factor=8.0)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.99  # balance loss >= 1 at optimum E*sum(f*p)

    # oracle: dense per-token expert mixture with the same top-k weights
    logits = x.reshape(-1, d) @ p["w_router"]
    probs = jax.nn.softmax(logits, -1)
    tv, ti = jax.lax.top_k(probs, cfg.top_k)
    tv = tv / tv.sum(-1, keepdims=True)
    xf = x.reshape(-1, d)
    y_ref = jnp.zeros_like(xf)
    for e in range(E):
        h = L.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        o = h @ p["w_down"][e]
        w = jnp.where(ti == e, tv, 0.0).sum(-1)
        y_ref = y_ref + o * w[:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)),
                               np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity factor ~0 every token drops -> output ~ 0."""
    cfg = _moe_cfg()
    p = {
        "w_router": jnp.ones((16, 8)),
        "w_gate": jnp.ones((8, 16, 32)),
        "w_up": jnp.ones((8, 16, 32)),
        "w_down": jnp.ones((8, 32, 16)),
    }
    x = jnp.ones((1, 64, 16))
    y, _ = L.moe_layer(p, x, MI1, cfg, capacity_factor=1e-9)
    # capacity C=1 -> at most top_k * E tokens receive any output
    nonzero_tokens = int((jnp.abs(y.reshape(-1, 16)).sum(-1) > 0).sum())
    assert nonzero_tokens <= cfg.top_k * cfg.n_experts, nonzero_tokens


def test_ce_loss_matches_naive():
    V, d, N = 50, 8, 12
    ks = jax.random.split(jax.random.key(0), 3)
    h = jax.random.normal(ks[0], (2, N // 2, d))
    table = jax.random.normal(ks[1], (V, d)) * 0.5
    labels = jax.random.randint(ks[2], (2, N // 2), 0, V - 10)
    loss, n = L.lm_head_loss(h, table, labels, MI1, vocab_real=V - 8)
    logits = np.asarray(h.reshape(-1, d) @ table.T, np.float64)
    logits[:, V - 8:] = -np.inf
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) \
        + logits.max(-1)
    lab = np.asarray(labels).reshape(-1)
    ref = (lse - logits[np.arange(len(lab)), lab]).mean()
    np.testing.assert_allclose(float(loss), ref, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_masked_labels_excluded(seed):
    V, d = 32, 8
    ks = jax.random.split(jax.random.key(seed), 3)
    h = jax.random.normal(ks[0], (1, 8, d))
    table = jax.random.normal(ks[1], (V, d))
    labels = jax.random.randint(ks[2], (1, 8), 0, V)
    masked = labels.at[0, :4].set(-1)
    loss_m, n = L.lm_head_loss(h, table, masked, MI1, vocab_real=V)
    loss_h, _ = L.lm_head_loss(h[:, 4:], table, labels[:, 4:], MI1,
                               vocab_real=V)
    assert int(n) == 4
    np.testing.assert_allclose(float(loss_m), float(loss_h), rtol=1e-5)
