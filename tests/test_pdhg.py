"""repro.pdhg — the restarted first-order backend.

Covers the convergence certificate (``solve_pdhg_with_stats``),
packed-vs-AoS agreement, infeasible/ragged classification against the
exact Seidel reference, the two solver-hardening regressions found
while building the backend (far-from-origin optima that need the
``||b||_inf`` rescale, and near-degenerate wedge vertices that need
the crossover polish), and the SolverSpec front-end wiring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (adversarial_lp, infeasible_lp, make_batch, pack,
                        ragged_feasible_lp, random_feasible_lp)
from repro.pdhg import (DEFAULT_ITER_BLOCK, PDHGStats, solve_pdhg,
                        solve_pdhg_packed, solve_pdhg_with_stats)
from repro.solver import SolverSpec, get_solver

TOL = 1e-5
# f32 objective agreement vs the exact backends: relative KKT <= 1e-5
# bounds the objective gap through the (O(1)-conditioned) test LPs.
OBJ_RTOL = 2e-3
OBJ_ATOL = 2e-3


def _ref(lp):
    return get_solver(SolverSpec(backend="rgb")).solve(lp)


def _pdhg(lp, **kw):
    return get_solver(SolverSpec(backend="pdhg", tol=TOL, **kw)).solve(lp)


def _assert_matches_ref(lp, sol, label):
    ref = _ref(lp)
    np.testing.assert_array_equal(
        np.asarray(ref.feasible), np.asarray(sol.feasible),
        err_msg=f"feasibility mismatch: {label}")
    feas = np.asarray(ref.feasible)
    if feas.any():
        np.testing.assert_allclose(
            np.asarray(sol.objective)[feas],
            np.asarray(ref.objective)[feas],
            rtol=OBJ_RTOL, atol=OBJ_ATOL,
            err_msg=f"objective mismatch: {label}")


def test_converges_with_certificate():
    lp = random_feasible_lp(jax.random.key(0), 32, 48)
    sol, st = solve_pdhg_with_stats(lp, tol=TOL)
    assert isinstance(st, PDHGStats)
    conv = np.asarray(st.converged)
    assert conv.all(), f"{int((~conv).sum())}/32 unconverged"
    assert (np.asarray(st.kkt) <= TOL).all() or conv.all()
    assert (np.asarray(st.iterations) >= 1).all()
    assert (np.asarray(st.restarts) >= 0).all()
    _assert_matches_ref(lp, sol, "random-feasible")


def test_packed_matches_aos():
    lp = ragged_feasible_lp(jax.random.key(3), 8, 24, m_min=4)
    a = solve_pdhg(lp, tol=TOL)
    p = solve_pdhg_packed(pack(lp), tol=TOL)
    np.testing.assert_array_equal(np.asarray(a.feasible),
                                  np.asarray(p.feasible))
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(p.x),
                               rtol=1e-5, atol=1e-5)


def test_infeasible_classified():
    lp = infeasible_lp(4, 12)
    sol, st = solve_pdhg_with_stats(lp, tol=TOL)
    assert not np.asarray(sol.feasible).any()
    _assert_matches_ref(lp, sol, "infeasible")


def test_ragged_and_adversarial_match_reference():
    for label, lp in [
        ("ragged", ragged_feasible_lp(jax.random.key(9), 10, 32,
                                      m_min=3)),
        ("adversarial", adversarial_lp(4, 24)),
    ]:
        _assert_matches_ref(lp, _pdhg(lp), label)


def test_far_origin_optimum_rescale_regression():
    # Optimum at (2000, 1500) with ||b||_inf = 2000: without the
    # per-problem max(1, ||b||_inf) rescale the fixed 1e4 box dwarfs
    # the step geometry and PDHG stalls far from the vertex.
    A = jnp.array([[[1.0, 0.0], [0.0, 1.0],
                    [-1.0, 0.0], [0.0, -1.0]]])
    b = jnp.array([[2000.0, 1500.0, 0.0, 0.0]])
    c = jnp.array([[1.0, 1.0]])
    lp = make_batch(A, b, c)
    sol, st = solve_pdhg_with_stats(lp, tol=TOL)
    assert bool(np.asarray(sol.feasible)[0])
    assert bool(np.asarray(st.converged)[0]), np.asarray(st.kkt)
    np.testing.assert_allclose(np.asarray(sol.objective)[0], 3500.0,
                               rtol=1e-4)


def test_narrow_wedge_crossover_polish_regression():
    # Two near-antiparallel normals form a wedge with vertex far from
    # the origin; the iterate crawls along the wedge but the top-2 dual
    # rows name the active faces, so the crossover polish must land the
    # exact vertex.
    v = jnp.array([1821.0, 1186.0])
    a1, a2 = 0.7, 0.7 + np.pi - 0.0024
    n1 = jnp.array([np.cos(a1), np.sin(a1)])
    n2 = jnp.array([np.cos(a2), np.sin(a2)])
    c = (n1 + n2)  # objective in the cone of the active normals
    A = jnp.stack([n1, n2])[None, :, :]
    b = jnp.array([[float(n1 @ v), float(n2 @ v)]])
    lp = make_batch(A, b, c[None, :])
    sol = _pdhg(lp)
    assert bool(np.asarray(sol.feasible)[0])
    np.testing.assert_allclose(np.asarray(sol.x)[0], np.asarray(v),
                               rtol=1e-3, atol=1e-2)
    # |c| ~ 2e-3 here (the near-antiparallel normals almost cancel), so
    # the objective inherits the x error scaled down by |c|: compare
    # absolutely at that scale.
    np.testing.assert_allclose(np.asarray(sol.objective)[0],
                               float(c @ v), rtol=1e-3, atol=1e-3)


def test_restarts_fire_with_short_period():
    lp = random_feasible_lp(jax.random.key(4), 8, 64)
    _, st = solve_pdhg_with_stats(lp, tol=TOL,
                                  iter_block=DEFAULT_ITER_BLOCK,
                                  restart_period=DEFAULT_ITER_BLOCK)
    # with period == one block, any problem that runs a few blocks
    # must have restarted at least once
    iters = np.asarray(st.iterations)
    restarts = np.asarray(st.restarts)
    ran_long = iters >= 3 * DEFAULT_ITER_BLOCK
    if ran_long.any():
        assert (restarts[ran_long] >= 1).all(), (iters, restarts)


def test_solver_spec_front_end_matches_direct_call():
    lp = random_feasible_lp(jax.random.key(6), 8, 32)
    pb = pack(lp)
    via_spec = get_solver(SolverSpec(backend="pdhg", tol=TOL,
                                     iter_block=64,
                                     restart_period=1024)).solve(pb)
    direct = solve_pdhg_packed(pb, tol=TOL, iter_block=64,
                               restart_period=1024)
    np.testing.assert_array_equal(np.asarray(via_spec.feasible),
                                  np.asarray(direct.feasible))
    # The front end normalizes constraint rows before solving, so the
    # iterates agree to f32 rounding rather than bit-for-bit.
    np.testing.assert_allclose(np.asarray(via_spec.x),
                               np.asarray(direct.x), rtol=1e-5,
                               atol=1e-4)


def test_stats_is_pytree():
    lp = random_feasible_lp(jax.random.key(8), 4, 16)

    @jax.jit
    def run(batch):
        return solve_pdhg_with_stats(batch, tol=TOL)

    sol, st = run(lp)
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == len(dataclasses.fields(PDHGStats))
    assert all(np.asarray(leaf).shape == (4,) for leaf in leaves)
