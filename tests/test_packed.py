"""PackedLPBatch — the canonical SoA constraint layout: lossless
conversions, packed-native batch utilities as bit-identical twins of the
AoS ones, pytree/jit behaviour, and the pack-call counter."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.packed as packed_mod
from repro.core import (LPBatch, PackedLPBatch, concat_batches,
                        concat_packed, make_batch, normalize_batch,
                        normalize_packed, pack, pack_call_count,
                        pad_batch, pad_batch_dim, pad_packed,
                        pad_packed_batch_dim, ragged_feasible_lp,
                        random_feasible_lp, shuffle_batch, shuffle_packed,
                        split_batch, split_packed, unpack)
from repro.kernels import ops


def _assert_batches_equal(a: LPBatch, b: LPBatch):
    np.testing.assert_array_equal(np.asarray(a.A), np.asarray(b.A))
    np.testing.assert_array_equal(np.asarray(a.b), np.asarray(b.b))
    np.testing.assert_array_equal(np.asarray(a.c), np.asarray(b.c))
    np.testing.assert_array_equal(np.asarray(a.m_valid),
                                  np.asarray(b.m_valid))


# -- conversions ---------------------------------------------------------

def test_pack_unpack_lossless():
    lp = ragged_feasible_lp(jax.random.key(0), 6, 23)
    pb = pack(lp)
    assert isinstance(pb, PackedLPBatch)
    assert pb.L.shape == (6, 4, 23)
    assert pb.c.shape == (6, 2)
    assert pb.m_valid.shape == (6, 1)
    _assert_batches_equal(unpack(pb), lp)
    # convenience methods mirror the functions
    _assert_batches_equal(lp.pack().unpack(), lp)


def test_pack_layout_rows():
    lp = random_feasible_lp(jax.random.key(1), 3, 7)
    pb = pack(lp)
    np.testing.assert_array_equal(np.asarray(pb.ax),
                                  np.asarray(lp.A[..., 0]))
    np.testing.assert_array_equal(np.asarray(pb.ay),
                                  np.asarray(lp.A[..., 1]))
    np.testing.assert_array_equal(np.asarray(pb.b), np.asarray(lp.b))
    assert np.all(np.asarray(pb.L[:, 3, :]) == 0.0)


def test_pack_with_m_pad_neutral_tail():
    lp = random_feasible_lp(jax.random.key(2), 4, 10)
    pb = pack(lp, m_pad=16)
    assert pb.m_pad == 16
    # tail columns are the neutral constraint 0*x <= 1
    assert np.all(np.asarray(pb.L[:, 0:2, 10:]) == 0.0)
    assert np.all(np.asarray(pb.L[:, 2, 10:]) == 1.0)
    # m_valid untouched: unpack keeps padding inert for the solvers
    np.testing.assert_array_equal(np.asarray(pb.m_valid[:, 0]),
                                  np.asarray(lp.m_valid))
    with pytest.raises(ValueError):
        pack(lp, m_pad=5)


# -- packed-native twins of the lp.* batch utilities ---------------------

def test_pad_packed_matches_pad_batch():
    lp = ragged_feasible_lp(jax.random.key(3), 5, 12)
    _assert_batches_equal(unpack(pad_packed(pack(lp), 20)),
                          pad_batch(lp, 20))
    with pytest.raises(ValueError):
        pad_packed(pack(lp), 4)


def test_pad_packed_batch_dim_neutral_problems():
    lp = ragged_feasible_lp(jax.random.key(4), 3, 9)
    pp = pad_packed_batch_dim(pack(lp), 8)
    assert pp.batch == 8
    _assert_batches_equal(unpack(pp), pad_batch_dim(lp, 8))
    _assert_batches_equal(split_packed(pp, [3], allow_remainder=True)[0]
                          .unpack(), lp)
    with pytest.raises(ValueError):
        pad_packed_batch_dim(pack(lp), 2)


def test_concat_split_packed_roundtrip():
    b1 = ragged_feasible_lp(jax.random.key(5), 4, 10)
    b2 = ragged_feasible_lp(jax.random.key(6), 3, 25)
    fused = concat_packed([pack(b1), pack(b2)])
    assert fused.batch == 7 and fused.m_pad == 25
    _assert_batches_equal(unpack(fused), concat_batches([b1, b2]))
    back1, back2 = split_packed(fused, [4, 3])
    _assert_batches_equal(unpack(back2), pack(b2).unpack())
    assert back1.m_pad == 25
    with pytest.raises(ValueError):
        split_packed(fused, [4, 2])      # silent remainder rejected
    with pytest.raises(ValueError):
        split_packed(fused, [4, 4])      # overflow rejected
    with pytest.raises(ValueError):
        concat_packed([])


def test_normalize_packed_bit_identical_to_aos():
    lp = ragged_feasible_lp(jax.random.key(7), 6, 15)
    # scale the batch so normalisation actually does arithmetic
    lp = LPBatch(A=lp.A * 3.7, b=lp.b * 3.7, c=lp.c, m_valid=lp.m_valid)
    _assert_batches_equal(unpack(normalize_packed(pack(lp))),
                          normalize_batch(lp))


def test_shuffle_packed_bit_identical_to_aos():
    lp = ragged_feasible_lp(jax.random.key(8), 5, 17)
    key = jax.random.key(42)
    _assert_batches_equal(unpack(shuffle_packed(key, pack(lp))),
                          shuffle_batch(key, lp))


def test_split_batch_packed_matches_aos():
    lp = random_feasible_lp(jax.random.key(9), 8, 6)
    for p_aos, p_soa in zip(split_batch(lp, [5, 3]),
                            split_packed(pack(lp), [5, 3])):
        _assert_batches_equal(p_aos, unpack(p_soa))


# -- pytree / jit behaviour ----------------------------------------------

def test_packed_is_pytree():
    pb = pack(random_feasible_lp(jax.random.key(10), 4, 8))
    leaves = jax.tree_util.tree_leaves(pb)
    assert len(leaves) == 3
    # transparently traceable: jit over the dataclass
    f = jax.jit(lambda p: dataclasses.replace(p, L=p.L * 2.0))
    doubled = f(pb)
    np.testing.assert_allclose(np.asarray(doubled.L),
                               np.asarray(pb.L) * 2.0)


def test_packed_dtype_follows_batch():
    lp = make_batch(np.ones((2, 3, 2), np.float32), np.ones((2, 3)),
                    np.ones((2, 2)))
    pb = pack(lp)
    assert pb.L.dtype == jnp.float32 and pb.c.dtype == jnp.float32
    assert pb.m_valid.dtype == jnp.int32


# -- pack-call accounting ------------------------------------------------

def test_pack_call_counter():
    lp = random_feasible_lp(jax.random.key(11), 2, 5)
    n0 = pack_call_count()
    pack(lp)
    assert pack_call_count() == n0 + 1
    ops.pack_constraints(lp)             # compat wrapper counts too
    assert pack_call_count() == n0 + 2
    # packed-native ops never repack
    pb = pack(lp)
    n1 = pack_call_count()
    normalize_packed(shuffle_packed(jax.random.key(0), pad_packed(pb, 8)))
    unpack(pb)
    assert pack_call_count() == n1
    assert packed_mod.pack_call_count() == n1
