"""The repro.tune autotuning subsystem: table semantics (keys, buckets,
persistence, merge), candidate-space validity, the measured runner, and
— the load-bearing contract — SolverSpec resolution precedence
*explicit > table > heuristic* with graceful miss fallback."""
import json

import jax
import numpy as np
import pytest

from repro.core import pack, random_feasible_lp
from repro.kernels.batch_lp import LANE
from repro.solver import SolverSpec, solve_with_spec
from repro.tune import (Candidate, TableEntry, TableKey, TuningTable,
                        bucket_pow2, candidate_space, current_device_kind,
                        default_table, device_platform, measure,
                        normalize_device_kind, representative_batch,
                        results_to_entries, set_active_table, tune,
                        tune_shape, use_table)
from repro.tune.table import SCHEMA_VERSION


def _key(device="cpu", backend="rgb", dtype="float32", m_bucket=32,
         batch_bucket=16):
    return TableKey(device, backend, dtype, m_bucket, batch_bucket)


def _entry(tile=16, chunk=64, us=1.0, us_iqr=0.0, k=1, **kw):
    return TableEntry(_key(**kw), tile=tile, chunk=chunk, us_per_lp=us,
                      us_iqr=us_iqr, k=k)


# -- table semantics ------------------------------------------------------

def test_bucket_pow2_ladder():
    assert bucket_pow2(1, 8) == 8
    assert bucket_pow2(8, 8) == 8
    assert bucket_pow2(9, 8) == 16
    assert bucket_pow2(700, 8) == 1024
    with pytest.raises(ValueError):
        bucket_pow2(0, 8)


def test_device_kind_normalisation():
    assert normalize_device_kind("TPU v4") == "tpu-v4"
    assert normalize_device_kind("  NVIDIA  A100 ") == "nvidia-a100"
    assert device_platform("TPU v5 lite") == "tpu"
    assert device_platform("cpu") == "cpu"
    # keys normalise on construction
    assert _key(device="TPU v4").device_kind == "tpu-v4"


def test_table_put_get_lookup_buckets():
    t = TuningTable([_entry()])
    assert t.get(_key()) is not None
    # lookup buckets raw shapes onto the ladder: m=21 -> 32, batch=9 -> 16
    hit = t.lookup(backend="rgb", dtype="float32", m=21, batch=9,
                   device_kind="cpu")
    assert hit is not None and (hit.tile, hit.chunk) == (16, 64)
    # misses: other bucket, backend, dtype, device
    assert t.lookup(backend="rgb", dtype="float32", m=500, batch=9,
                    device_kind="cpu") is None
    assert t.lookup(backend="naive", dtype="float32", m=21, batch=9,
                    device_kind="cpu") is None
    assert t.lookup(backend="rgb", dtype="float64", m=21, batch=9,
                    device_kind="cpu") is None
    assert t.lookup(backend="rgb", dtype="float32", m=21, batch=9,
                    device_kind="tpu-v4") is None


def test_table_lookup_fallbacks():
    # platform-family fallback: one "tpu" row covers every tpu model
    fam = TuningTable([_entry(device="tpu", tile=64, chunk=0)])
    hit = fam.lookup(backend="rgb", dtype="float32", m=21, batch=9,
                     device_kind="TPU v4")
    assert hit is not None and hit.tile == 64
    # exact device beats the family row
    both = TuningTable([_entry(device="tpu", tile=64, chunk=0),
                        _entry(device="tpu-v4", tile=8, chunk=0)])
    assert both.lookup(backend="rgb", dtype="float32", m=21, batch=9,
                       device_kind="tpu v4").tile == 8
    # batch-wildcard rung (batch_bucket=0) catches unknown batch sizes
    wild = TuningTable([_entry(batch_bucket=0, tile=128, chunk=0)])
    assert wild.lookup(backend="rgb", dtype="float32", m=21,
                       device_kind="cpu").tile == 128
    assert wild.lookup(backend="rgb", dtype="float32", m=21, batch=4096,
                       device_kind="cpu").tile == 128


def test_table_merge_keeps_faster():
    slow = TuningTable([_entry(tile=8, us=9.0)])
    fast = TuningTable([_entry(tile=16, us=2.0)])
    assert slow.merge(fast).get(_key()).tile == 16
    # merging the slower one back does not regress
    assert fast.merge(TuningTable([_entry(tile=8, us=9.0)])) \
        .get(_key()).tile == 16
    # disjoint keys union
    other = TuningTable([_entry(m_bucket=64, tile=32, us=1.0)])
    assert len(fast.merge(other)) == 2


def test_table_merge_rejects_improvements_inside_noise_band():
    """A candidate faster by less than the recorded spread is noise,
    not an improvement: the incumbent stays.  The dead zone is the
    larger of the two entries' IQRs."""
    incumbent = TuningTable([_entry(tile=16, us=10.0, us_iqr=2.0, k=5)])
    # 9.0 is faster, but only by 1.0 < the 2.0 noise band
    incumbent.merge(TuningTable([_entry(tile=8, us=9.0, us_iqr=0.1,
                                        k=5)]))
    assert incumbent.get(_key()).tile == 16
    # the challenger's own spread also widens the band
    incumbent.merge(TuningTable([_entry(tile=8, us=8.5, us_iqr=3.0,
                                        k=5)]))
    assert incumbent.get(_key()).tile == 16
    # a win beyond the band replaces
    incumbent.merge(TuningTable([_entry(tile=8, us=7.5, us_iqr=0.1,
                                        k=5)]))
    assert incumbent.get(_key()).tile == 8
    assert incumbent.get(_key()).us_per_lp == 7.5
    # zero recorded spread degrades to the old strictly-faster rule
    legacy = TuningTable([_entry(tile=16, us=10.0)])
    legacy.merge(TuningTable([_entry(tile=8, us=9.99)]))
    assert legacy.get(_key()).tile == 8


def test_table_merge_measured_vs_seed_precedence():
    """Measured entries always replace heuristic seeds (whatever the
    timings claim) and seeds never replace measurements."""
    seed = _entry(tile=32, us=0.001)
    seed = TableEntry(seed.key, tile=32, chunk=64, us_per_lp=0.001,
                      source="heuristic-seed")
    t = TuningTable([seed])
    # a much "slower" measured entry still wins over the seed sentinel
    t.merge(TuningTable([_entry(tile=8, us=100.0, us_iqr=5.0, k=3)]))
    assert t.get(_key()).source == "measured"
    assert t.get(_key()).tile == 8
    # and the seed cannot claw its way back
    t.merge(TuningTable([seed]))
    assert t.get(_key()).source == "measured"


def test_entry_stats_fields_and_json_roundtrip(tmp_path):
    """(median, iqr, k) ride along in the table: validated, persisted,
    and defaulted when loading rows written before the stats slice."""
    e = _entry(us=2.0, us_iqr=0.25, k=7)
    assert e.noise_band_us == 0.25
    with pytest.raises(ValueError):
        _entry(us_iqr=-0.1)
    with pytest.raises(ValueError):
        _entry(k=0)
    t = TuningTable([e])
    p = t.save(tmp_path / "stats.json")
    back = TuningTable.load(p)
    got = back.get(_key())
    assert (got.us_iqr, got.k) == (0.25, 7)
    assert back == t
    # same schema version: rows written without the stats fields load
    # with (0.0, 1) defaults instead of failing the version check
    doc = json.loads(p.read_text())
    assert doc["version"] == SCHEMA_VERSION
    for row in doc["entries"]:
        del row["us_iqr"], row["k"]
    legacy = TuningTable.from_json(doc)
    got = legacy.get(_key())
    assert (got.us_iqr, got.k) == (0.0, 1)


def test_measure_stats_and_tune_record_spread():
    """measure_stats returns (median, iqr, k) and the tuner threads
    the spread through TuneResult into table entries."""
    from repro.tune import measure_stats
    pb = representative_batch(16, 8)
    solver = SolverSpec(backend="rgb", tile=8, chunk=0).build()
    med, iqr, k = measure_stats(solver.solve, pb, warmup=1, iters=5)
    assert med > 0.0 and iqr >= 0.0 and k == 5
    # a single repetition has no spread by definition
    _, iqr1, k1 = measure_stats(solver.solve, pb, warmup=0, iters=1)
    assert iqr1 == 0.0 and k1 == 1
    results = tune_shape(16, 8, backends=("rgb",), warmup=1, iters=3)
    assert all(r.k == 3 and r.iqr_seconds >= 0.0 for r in results)
    (entry,) = results_to_entries(results)
    winner = results[0]
    assert entry.k == 3
    assert entry.us_iqr == pytest.approx(winner.us_iqr)


def test_table_json_roundtrip(tmp_path):
    t = TuningTable([_entry(), _entry(backend="kernel", tile=64, chunk=128,
                                      us=0.5),
                     _entry(device="tpu", dtype="float64", us=3.0)])
    p = t.save(tmp_path / "t.json")
    assert TuningTable.load(p) == t
    doc = json.loads(p.read_text())
    assert doc["version"] == SCHEMA_VERSION
    # version mismatch is rejected loudly (the CI cache-bust contract)
    doc["version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        TuningTable.from_json(doc)


def test_entry_validation():
    with pytest.raises(ValueError):
        TableEntry(_key(), tile=0, chunk=0, us_per_lp=1.0)
    with pytest.raises(ValueError):
        TableEntry(_key(), tile=8, chunk=-1, us_per_lp=1.0)
    with pytest.raises(ValueError):
        TableEntry(_key(), tile=8, chunk=0, us_per_lp=float("nan"))


def test_default_table_loads():
    """The bundled table must parse (entries may be empty on exotic
    platforms but the file itself is part of the package contract)."""
    t = default_table()
    assert isinstance(t, TuningTable)
    for e in t.entries():
        assert e.key.backend in ("naive", "rgb", "kernel", "pdhg")
        assert e.tile >= 1 and e.chunk >= 0


# -- candidate space ------------------------------------------------------

def test_candidate_space_validity():
    cands = candidate_space(128, 256, device_kind="cpu",
                            backends=("naive", "rgb", "kernel"))
    assert Candidate("naive", 32, 0) in cands
    kinds = {c.backend for c in cands}
    assert kinds == {"naive", "rgb", "kernel"}
    for c in cands:
        assert c.tile >= 1 and c.chunk >= 0
        if c.backend == "rgb" and c.chunk:
            assert c.chunk < 128          # chunk >= m_pad is degenerate
        if c.backend == "kernel":
            assert c.tile % 8 == 0        # sublane multiples
            if c.chunk:
                m_lane = -(-128 // LANE) * LANE
                assert m_lane % c.chunk == 0
    # deterministic enumeration (the tuner's grid must be reproducible)
    assert cands == candidate_space(128, 256, device_kind="cpu",
                                    backends=("naive", "rgb", "kernel"))
    # tiny batches keep at least one rung per backend
    tiny = candidate_space(8, 2, device_kind="cpu", backends=("rgb",))
    assert {c.tile for c in tiny} == {8}
    with pytest.raises(ValueError):
        candidate_space(128, 256, dtype="int8")
    with pytest.raises(ValueError):
        candidate_space(0, 4)


def test_default_backends_by_platform():
    from repro.tune import default_backends
    assert default_backends("cpu") == ("naive", "rgb", "pdhg")
    assert default_backends("tpu-v4") == ("rgb", "kernel", "pdhg")


def test_pdhg_candidate_space():
    """pdhg candidates carry (iter_block, restart_period) in the
    (tile, chunk) slots; a period shorter than one block is dropped."""
    cands = candidate_space(2048, 64, backends=("pdhg",))
    assert cands and all(c.backend == "pdhg" for c in cands)
    for c in cands:
        assert c.tile >= 1                       # iter_block
        assert c.chunk == 0 or c.chunk >= c.tile  # period >= one block
        assert c.label() == f"pdhg/ib{c.tile}/rp{c.chunk}"
    # shape-independent schedule: the grid is the same at any shape
    assert cands == candidate_space(64, 8, backends=("pdhg",))


# -- runner ---------------------------------------------------------------

def test_measure_is_fenced_and_positive():
    pb = representative_batch(16, 8)
    solver = SolverSpec(backend="rgb", tile=8, chunk=0).build()
    s = measure(solver.solve, pb, warmup=1, iters=3)
    assert s > 0.0
    with pytest.raises(ValueError):
        measure(solver.solve, pb, iters=0)


def test_tune_shape_records_real_timings():
    results = tune_shape(16, 8, backends=("rgb",), warmup=1, iters=1)
    assert results and all(r.seconds > 0 for r in results)
    assert results == sorted(results, key=lambda r: r.seconds)
    entries = results_to_entries(results)
    assert len(entries) == 1  # one winner per backend
    e = entries[0]
    assert e.key.backend == "rgb"
    assert e.key.m_bucket == 16 and e.key.batch_bucket == 8
    assert e.key.device_kind == current_device_kind()
    # the winner is the fastest candidate's geometry
    assert (e.tile, e.chunk) == (results[0].candidate.tile,
                                 results[0].candidate.chunk)


def test_tune_merges_into_table():
    seen = []
    table = tune([(16, 8)], backends=("rgb",), warmup=1, iters=1,
                 on_result=seen.append)
    assert len(table) == 1 and seen
    hit = table.lookup(backend="rgb", dtype="float32", m=16, batch=8)
    assert hit is not None


# -- resolution precedence (the acceptance contract) ----------------------

def _synthetic_table(tile=16, chunk=64):
    return TuningTable([TableEntry(
        TableKey(current_device_kind(), "rgb", "float32", m_bucket=32,
                 batch_bucket=16), tile=tile, chunk=chunk,
        us_per_lp=1.0)])


def test_table_entry_changes_resolved_geometry():
    """A synthetic TuningTable entry measurably changes the resolved
    (tile, chunk) for a matching SolverSpec — no real timing needed."""
    spec = SolverSpec(backend="rgb")
    with use_table(TuningTable()):
        base = spec.resolve_for_shape(21, 9)
    assert (base.tile, base.chunk) == (32, 0)      # heuristic floor
    with use_table(_synthetic_table(tile=16, chunk=64)):
        tuned = spec.resolve_for_shape(21, 9)
    assert (tuned.tile, tuned.chunk) == (16, 64)
    assert (tuned.tile, tuned.chunk) != (base.tile, base.chunk)


def test_explicit_values_beat_table():
    with use_table(_synthetic_table(tile=16, chunk=64)):
        full = SolverSpec(backend="rgb", tile=8,
                          chunk=0).resolve_for_shape(21, 9)
        assert (full.tile, full.chunk) == (8, 0)
        # partial: explicit tile, tuned chunk (and vice versa)
        half = SolverSpec(backend="rgb", tile=8).resolve_for_shape(21, 9)
        assert (half.tile, half.chunk) == (8, 64)
        other = SolverSpec(backend="rgb", chunk=0).resolve_for_shape(21, 9)
        assert (other.tile, other.chunk) == (16, 0)


def test_table_miss_falls_back_never_errors():
    with use_table(_synthetic_table()):
        # different m bucket, batch bucket, dtype: all miss -> heuristics
        assert SolverSpec(backend="rgb").resolve_for_shape(
            500, 9).tile == 32
        assert SolverSpec(backend="rgb").resolve_for_shape(
            21, 4096).tile == 32
        n = SolverSpec(backend="naive").resolve_for_shape(21, 9)
        assert n.is_shape_resolved
    # a pathological active table must never take resolution down
    class _Boom:
        def lookup(self, **kw):
            raise RuntimeError("boom")

        def lookup_best_backend(self, **kw):
            raise RuntimeError("boom")
    set_active_table(_Boom())
    try:
        r = SolverSpec(backend="rgb").resolve_for_shape(21, 9)
        assert (r.tile, r.chunk) == (32, 0)
    finally:
        set_active_table(None)


def test_kernel_chunk_from_table_must_divide_lane_rounded_m():
    """A bucketed kernel entry can carry a chunk that does not divide a
    specific shape's lane-rounded m; resolution drops it to dense
    instead of producing an invalid launch."""
    t = TuningTable([TableEntry(
        TableKey(current_device_kind(), "kernel", "float32",
                 m_bucket=bucket_pow2(384, 8), batch_bucket=16),
        tile=32, chunk=256, us_per_lp=1.0)])
    with use_table(t):
        # m=384 lane-rounds to 384, and 384 % 256 != 0 -> chunk drops
        spec = SolverSpec(backend="kernel").resolve_for_shape(384, 16,
                                                              "cpu")
        assert spec.chunk == 0 and spec.tile == 32
        # m=256 lane-rounds to 256: the tuned chunk is valid, kept
        t2 = TuningTable([TableEntry(
            TableKey(current_device_kind(), "kernel", "float32",
                     m_bucket=bucket_pow2(256, 8), batch_bucket=16),
            tile=32, chunk=128, us_per_lp=1.0)])
        with use_table(t2):
            spec = SolverSpec(backend="kernel").resolve_for_shape(
                256, 16, "cpu")
            assert spec.chunk == 128


def test_auto_backend_picks_measured_winner():
    kind = current_device_kind()
    mk = lambda backend, us: TableEntry(
        TableKey(kind, backend, "float32", m_bucket=32, batch_bucket=16),
        tile=32, chunk=0, us_per_lp=us)
    t = TuningTable([mk("naive", 0.5), mk("rgb", 2.0)])
    with use_table(t):
        spec = SolverSpec(backend="auto").resolve_for_shape(21, 9)
        assert spec.backend == "naive"
    # no measurements: platform default stands
    with use_table(TuningTable()):
        spec = SolverSpec(backend="auto").resolve_for_shape(21, 9)
        assert spec.backend == ("kernel" if jax.default_backend() == "tpu"
                                else "rgb")


def test_auto_routes_small_m_kernel_big_m_pdhg():
    """The crossover acceptance contract: with measurements saying the
    kernel wins at small m and pdhg wins at large m, ``backend="auto"``
    routes each shape to its measured winner — and a pdhg winner's
    geometry slots come back as the (iter_block, restart_period)
    schedule, not as tile/chunk."""
    kind = current_device_kind()
    mk = lambda backend, mb, tile, chunk, us: TableEntry(
        TableKey(kind, backend, "float32", m_bucket=mb, batch_bucket=0),
        tile=tile, chunk=chunk, us_per_lp=us)
    t = TuningTable([
        mk("kernel", 64, 8, 0, 1.0),
        mk("pdhg", 64, 64, 512, 40.0),
        mk("kernel", 4096, 8, 0, 900.0),
        mk("pdhg", 4096, 128, 2048, 30.0),
    ])
    with use_table(t):
        small = SolverSpec(backend="auto").resolve_for_shape(48, 32)
        big = SolverSpec(backend="auto").resolve_for_shape(4000, 32)
    assert small.backend == "kernel"
    assert (small.tile, small.chunk) == (8, 0)
    assert big.backend == "pdhg"
    assert (big.iter_block, big.restart_period) == (128, 2048)
    assert big.is_shape_resolved


def test_pdhg_schedule_resolution_precedence():
    """explicit > table > default for the pdhg iteration schedule."""
    from repro.pdhg import DEFAULT_ITER_BLOCK, DEFAULT_RESTART_PERIOD
    kind = current_device_kind()
    t = TuningTable([TableEntry(
        TableKey(kind, "pdhg", "float32", m_bucket=32, batch_bucket=16),
        tile=128, chunk=2048, us_per_lp=1.0)])
    with use_table(t):
        tuned = SolverSpec(backend="pdhg").resolve_for_shape(21, 9)
        assert (tuned.iter_block, tuned.restart_period) == (128, 2048)
        half = SolverSpec(backend="pdhg",
                          iter_block=32).resolve_for_shape(21, 9)
        assert (half.iter_block, half.restart_period) == (32, 2048)
    with use_table(TuningTable()):
        bare = SolverSpec(backend="pdhg").resolve_for_shape(21, 9)
        assert (bare.iter_block, bare.restart_period) == (
            DEFAULT_ITER_BLOCK, DEFAULT_RESTART_PERIOD)
    # tile/chunk are inert for pdhg but still pinned concrete so the
    # serving layer's shape-resolved consumers keep working
    assert bare.is_shape_resolved
    assert bare.tile is not None and bare.chunk is not None


def test_auto_backend_reaches_built_solver():
    """The shape-dependent auto choice must survive ``spec.build()``:
    the Solver keeps "auto" on its solving spec (resolution happens at
    trace time, per shape), while its introspection spec shows the
    platform default used on a table miss."""
    solver = SolverSpec(backend="auto").build()
    assert solver._solve_spec.backend == "auto"
    assert solver.spec.backend != "auto"
    kind = current_device_kind()
    t = TuningTable([TableEntry(
        TableKey(kind, "naive", "float32", m_bucket=32, batch_bucket=16),
        tile=32, chunk=0, us_per_lp=0.5)])
    lp = random_feasible_lp(jax.random.key(7), 9, 21)
    with use_table(t):
        tuned = solver.solve(lp)            # runs naive per the table
        ref = SolverSpec(backend="naive").build().solve(lp)
    np.testing.assert_array_equal(np.asarray(tuned.x), np.asarray(ref.x))


def test_tuned_solve_end_to_end_matches_untuned():
    """The tuned geometry changes the launch, not the answer: solving
    with a synthetic table active agrees with the untuned solve."""
    lp = random_feasible_lp(jax.random.key(3), 9, 21)
    spec = SolverSpec(backend="rgb")
    with use_table(TuningTable()):
        base = solve_with_spec(spec, lp)
    with use_table(_synthetic_table(tile=8, chunk=64)):
        tuned = solve_with_spec(spec, lp)
        tuned_packed = solve_with_spec(spec, pack(lp))
    np.testing.assert_array_equal(np.asarray(base.feasible),
                                  np.asarray(tuned.feasible))
    np.testing.assert_allclose(np.asarray(base.objective),
                               np.asarray(tuned.objective),
                               rtol=5e-4, atol=5e-4)
    # packed/AoS bit-identity holds under tuned geometry too
    np.testing.assert_array_equal(np.asarray(tuned.x),
                                  np.asarray(tuned_packed.x))
