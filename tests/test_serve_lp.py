"""Serving subsystem tests: bucketing math, flush policies, pipelined
dispatch/completion (overlap, backpressure, buffer-lease audit, failure
isolation), executable cache accounting, round-trip equivalence with
the direct solvers, and multi-device sharding (out-of-process)."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (concat_batches, make_batch, pack_call_count,
                        pad_batch_dim, ragged_feasible_lp, split_batch)
from repro.kernels import ops
from repro.solver import get_solver
from repro.serve_lp import (BatchScheduler, ExecSpec, ExecutableCache,
                            LaunchGroup, MeshLayout, ServeMetrics,
                            SolverSpec, as_executable, bucket_batch,
                            bucket_m, build_executable, plan_layout,
                            shape_ladder)
from repro.serve_lp.bench import BenchConfig, make_request, run_traffic
from repro.serve_lp.scheduler import _FlushBufferPool


def _mixed_requests(seed=0, ms=(3, 8, 37, 128, 130, 200), reps=2):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(reps):
        for m in ms:
            xstar = rng.uniform(-10, 10, 2)
            theta = rng.uniform(0, 2 * np.pi, m)
            A = np.stack([np.cos(theta), np.sin(theta)], -1)
            b = A @ xstar + rng.uniform(0.1, 3.0, m)
            phi = rng.uniform(0, 2 * np.pi)
            c = np.array([np.cos(phi), np.sin(phi)])
            reqs.append((A.astype(np.float32), b.astype(np.float32),
                         c.astype(np.float32)))
    return reqs


# -- bucketing -----------------------------------------------------------

def test_bucket_m_ladder():
    assert bucket_m(1) == 128
    assert bucket_m(128) == 128
    assert bucket_m(129) == 256
    assert bucket_m(700) == 1024
    assert bucket_m(1024) == 1024
    assert shape_ladder(1000) == [128, 256, 512, 1024]
    # dense solvers use a finer base so tiny LPs are not padded 16x
    assert bucket_m(3, base=8) == 8
    assert bucket_m(9, base=8) == 16
    assert bucket_m(130, base=8) == 256
    with pytest.raises(ValueError):
        bucket_m(0)


def test_scheduler_bucket_base_by_method():
    assert BatchScheduler(method="rgb").bucket_base == 8
    assert BatchScheduler(method="naive").bucket_base == 8
    assert BatchScheduler(method="kernel").bucket_base == 128


def test_bucket_batch_ladder():
    assert bucket_batch(1, 32) == 32
    assert bucket_batch(32, 32) == 32
    assert bucket_batch(33, 32) == 64
    assert bucket_batch(100, 32) == 128


def test_exec_spec_validation():
    # only the kernel has a LANE-layout requirement
    with pytest.raises(ValueError):
        ExecSpec(bucket_m=100, b_pad=32,
                 solver=SolverSpec(backend="kernel", tile=32))
    ExecSpec(bucket_m=16, b_pad=32, solver=SolverSpec(backend="rgb",
                                                      tile=32))
    # mesh sharding (default) owns padding: any positive b_pad is
    # legal; the legacy pmap path still needs whole equal shards
    ExecSpec(bucket_m=128, b_pad=33,
             solver=SolverSpec(backend="rgb", tile=32))
    with pytest.raises(ValueError):
        ExecSpec(bucket_m=128, b_pad=33,
                 solver=SolverSpec(backend="rgb", tile=32),
                 sharding="pmap")
    with pytest.raises(ValueError):
        ExecSpec(bucket_m=128, b_pad=32,
                 solver=SolverSpec(backend="rgb", tile=32),
                 sharding="banana")
    # b_pad padding needs a concrete tile (tile=None means "pick per
    # shape" on every backend now — the scheduler pins it per bucket
    # via resolve_for_shape before building an ExecSpec)
    with pytest.raises(ValueError):
        ExecSpec(bucket_m=128, b_pad=32,
                 solver=SolverSpec(backend="kernel"))
    with pytest.raises(ValueError):
        ExecSpec(bucket_m=128, b_pad=32,
                 solver=SolverSpec(backend="rgb"))
    with pytest.raises(TypeError):
        ExecSpec(bucket_m=128, b_pad=32, solver="rgb")


def test_exec_spec_keys_on_full_solver_spec():
    """Two schedulers with different solver specs must never alias
    executables: the whole SolverSpec is part of the cache key."""
    mk = lambda **kw: ExecSpec(bucket_m=16, b_pad=32,
                               solver=SolverSpec(backend="rgb", tile=32,
                                                 **kw))
    assert mk() == mk()
    assert hash(mk()) == hash(mk())
    assert mk(M=2.0e4) != mk()
    assert mk(seed=1, shuffle=True) != mk(shuffle=True)
    assert mk(normalize=False) != mk()
    # resolution canonicalises: auto==rgb on a non-TPU test backend
    if jax.default_backend() != "tpu":
        auto = ExecSpec(bucket_m=16, b_pad=32,
                        solver=SolverSpec(backend="auto", tile=32))
        assert auto == mk()


def test_scheduler_accepts_spec_and_rejects_mixed_kwargs():
    spec = SolverSpec(backend="rgb", tile=8, chunk=64)
    sched = BatchScheduler(spec, max_batch=4)
    assert sched.spec.tile == 8 and sched.spec.chunk == 64
    with pytest.raises(TypeError):
        BatchScheduler(spec, method="rgb")
    with pytest.raises(TypeError):
        BatchScheduler("rgb")
    # tile=None stays unset on the spec (pinned per bucket at flush
    # time); the legacy .tile view reports the serving default
    sched_default = BatchScheduler(SolverSpec(backend="rgb"))
    assert sched_default.spec.tile is None
    assert sched_default.tile == 32
    # shuffle specs are rejected: the flush-wide shuffle would make a
    # request's result depend on its position in the super-batch
    with pytest.raises(ValueError, match="shuffle"):
        BatchScheduler(SolverSpec(backend="rgb", shuffle=True))


# -- core batch utilities ------------------------------------------------

def test_concat_split_roundtrip():
    b1 = ragged_feasible_lp(jax.random.key(0), 5, 20)
    b2 = ragged_feasible_lp(jax.random.key(1), 3, 50)
    fused = concat_batches([b1, b2])
    assert fused.batch == 8 and fused.m == 50
    back1, back2 = split_batch(fused, [5, 3])
    np.testing.assert_array_equal(np.asarray(back1.A[:, :20]),
                                  np.asarray(b1.A))
    np.testing.assert_array_equal(np.asarray(back2.A), np.asarray(b2.A))
    np.testing.assert_array_equal(np.asarray(back1.m_valid),
                                  np.asarray(b1.m_valid))
    # padding rows of the shorter member are neutral
    assert np.all(np.asarray(back1.A[:, 20:]) == 0.0)
    assert np.all(np.asarray(back1.b[:, 20:]) == 1.0)


def test_pad_batch_dim_neutral():
    b = ragged_feasible_lp(jax.random.key(2), 3, 10)
    p = pad_batch_dim(b, 8)
    assert p.batch == 8
    assert np.all(np.asarray(p.m_valid[3:]) == 0)
    rgb = get_solver(SolverSpec(backend="rgb", tile=32, chunk=0))
    sol = rgb.solve(p)
    direct = rgb.solve(b)
    np.testing.assert_array_equal(np.asarray(sol.x[:3]),
                                  np.asarray(direct.x))


def test_pack_constraints_bucketed():
    b = ragged_feasible_lp(jax.random.key(3), 4, 30)
    L, c, mv = ops.pack_constraints(b, m_pad=256)
    assert L.shape == (4, 4, 256)
    with pytest.raises(ValueError):
        ops.pack_constraints(b, m_pad=100)  # not a LANE multiple
    with pytest.raises(ValueError):
        ops.pack_constraints(b, m_pad=0)


# -- flush policies ------------------------------------------------------

def test_size_triggered_flush():
    sched = BatchScheduler(max_batch=4, tile=8)
    reqs = _mixed_requests(ms=(9, 10, 11, 12), reps=1)  # one bucket (16)
    futs = [sched.submit(*r) for r in reqs]
    # 4th submit hit max_batch: dispatched inline, no flush()/thread
    # needed; the completion worker resolves the futures
    for f in futs:
        f.result(timeout=60.0)
    assert sched.pending() == 0
    assert sched.metrics.flush_reasons == {"size": 1}


def test_size_triggered_flush_sync_mode():
    """pipeline=False restores the stop-and-go contract: a size-
    triggered flush completes before submit returns."""
    sched = BatchScheduler(max_batch=4, tile=8, pipeline=False)
    futs = [sched.submit(*r) for r in
            _mixed_requests(ms=(9, 10, 11, 12), reps=1)]
    assert all(f.done() for f in futs)
    assert sched.metrics.flush_reasons == {"size": 1}
    assert sched.metrics.inflight_now == 0


def test_wait_triggered_flush():
    with BatchScheduler(max_batch=1000, max_wait_s=0.02, tile=8) as sched:
        futs = [sched.submit(*r) for r in
                _mixed_requests(ms=(5, 200), reps=1)]
        deadline = time.time() + 5.0
        while not all(f.done() for f in futs):
            assert time.time() < deadline, "wait-trigger never flushed"
            time.sleep(0.01)
        assert sched.metrics.flush_reasons.get("wait", 0) >= 1


def test_manual_flush_and_pending():
    sched = BatchScheduler(max_batch=1000, tile=8)
    futs = [sched.submit(*r) for r in _mixed_requests(reps=1)]
    assert sched.pending() == len(futs)
    n = sched.flush()
    assert n == len(futs)
    assert sched.pending() == 0
    sched.drain()          # flush() dispatches; drain() is the join
    assert all(f.done() for f in futs)


# -- packed flush path ---------------------------------------------------

@pytest.mark.parametrize("method,interpret", [("rgb", None),
                                              ("kernel", True)])
def test_flush_does_zero_repacks(method, interpret):
    """The serving hot path assembles flushes directly in the packed SoA
    layout: no AoS -> SoA conversion (core.packed.pack) may run during
    submit, flush, or result scatter — on any backend."""
    sched = BatchScheduler(method=method, max_batch=1000, tile=8,
                           interpret=interpret)
    reqs = _mixed_requests(reps=2)
    n0 = pack_call_count()
    futs = [sched.submit(*r) for r in reqs]
    sched.flush()
    for f in futs:
        f.result(timeout=120.0)
    # repeat flush on warm executables: still zero
    futs = [sched.submit(*r) for r in reqs]
    sched.flush()
    for f in futs:
        f.result(timeout=120.0)
    assert pack_call_count() == n0, (
        "serve_lp flush path performed an AoS->SoA repack")


def test_flush_buffers_reused_for_stable_bucket():
    """Steady traffic on a stable bucket must not reallocate the host
    flush buffers: the per-bucket pool allocates once and every later
    flush of that shape leases the same buffers back."""
    sched = BatchScheduler(method="rgb", max_batch=1000, tile=8)
    reqs = _mixed_requests(ms=(9, 10, 11, 12), reps=1)  # one bucket (16)
    results = []
    for round_ in range(4):
        futs = [sched.submit(*r) for r in reqs]
        sched.flush()
        results.append([f.result(timeout=60.0) for f in futs])
    assert sched.buffers.lease_count == 4
    assert sched.buffers.alloc_count == 1, (
        "stable bucket reallocated its flush buffers "
        f"({sched.buffers.alloc_count} allocations in 4 flushes)")
    # buffer reuse must not leak state between flushes
    for later in results[1:]:
        for a, b in zip(results[0], later):
            np.testing.assert_array_equal(a.x, b.x)
            assert a.feasible == b.feasible
    # a new bucket shape allocates its own set, once
    big = _mixed_requests(ms=(200, 210), reps=1)
    for round_ in range(2):
        futs = [sched.submit(*r) for r in big]
        sched.flush()
        for f in futs:
            f.result(timeout=60.0)
    assert sched.buffers.alloc_count == 2


def test_scheduler_pins_tuned_config_per_bucket():
    """A tuning-table entry matching a bucket's shape class changes the
    launch geometry of that bucket's executable; a miss keeps the
    serving default — and explicit spec values beat the table."""
    from repro.tune import (TableEntry, TableKey, TuningTable,
                            current_device_kind, use_table)
    entry = TableEntry(TableKey(current_device_kind(), "rgb", "float32",
                                m_bucket=16, batch_bucket=8), tile=8,
                       chunk=0, us_per_lp=1.0)
    req_small = _mixed_requests(ms=(9,), reps=1)[0]    # bucket_m 16
    req_large = _mixed_requests(ms=(70,), reps=1)[0]   # bucket_m 128
    with use_table(TuningTable([entry])):
        # fuse=False: this test is about *per-bucket* pinned geometry,
        # so the two buckets must flush as separate units
        sched = BatchScheduler(SolverSpec(backend="rgb"), max_batch=1000,
                               fuse=False)
        f1 = sched.submit(*req_small)
        f2 = sched.submit(*req_large)
        sched.flush()
        f1.result(timeout=60.0), f2.result(timeout=60.0)
        tiles = {k.bucket_m: k.solver.tile for k in sched.cache._cache}
        assert tiles[16] == 8, "tuned tile did not reach the ExecSpec"
        assert tiles[128] == 32, "table miss should keep the default"
        # explicit spec tile wins over the same table entry
        sched_exp = BatchScheduler(SolverSpec(backend="rgb", tile=16),
                                   max_batch=1000)
        f3 = sched_exp.submit(*req_small)
        sched_exp.flush()
        f3.result(timeout=60.0)
        assert all(k.solver.tile == 16 for k in sched_exp.cache._cache)


def test_submit_honors_spec_dtype():
    """Request buffers are assembled at the spec's dtype end-to-end
    (a float64 spec must not silently truncate through float32)."""
    sched = BatchScheduler(SolverSpec(backend="rgb", tile=8))
    req = _mixed_requests(ms=(5,), reps=1)[0]
    sched.submit(np.asarray(req[0], np.float64), req[1], req[2])
    q = next(iter(sched._queues.values()))
    assert q[0].ax.dtype == np.float32 and q[0].b.dtype == np.float32
    assert q[0].c.dtype == np.float32
    sched.flush()
    if jax.config.jax_enable_x64:
        s64 = BatchScheduler(SolverSpec(backend="rgb", tile=8,
                                        dtype="float64"))
        s64.submit(*req)
        q = next(iter(s64._queues.values()))
        assert q[0].ax.dtype == np.float64
        s64.flush()
    else:
        # x64 off: a float64 spec is rejected at construction, exactly
        # like the solver's own check
        with pytest.raises(ValueError, match="x64"):
            BatchScheduler(SolverSpec(backend="rgb", tile=8,
                                      dtype="float64"))


# -- round trips ---------------------------------------------------------

def test_roundtrip_bit_identical_rgb():
    """Mixed-shape requests through the scheduler give bit-identical
    results to a direct solve with the *same* SolverSpec."""
    spec = SolverSpec(backend="rgb", tile=32)
    sched = BatchScheduler(spec, max_batch=1000)
    solver = spec.build()
    reqs = _mixed_requests()
    futs = [sched.submit(*r) for r in reqs]
    sched.flush()
    for (A, b, c), f in zip(reqs, futs):
        r = f.result(timeout=60.0)
        direct = solver.solve(make_batch(A, b, c))
        assert bool(direct.feasible[0]) == r.feasible
        np.testing.assert_array_equal(np.asarray(direct.x[0]), r.x)


def test_roundtrip_kernel_interpret():
    sched = BatchScheduler(method="kernel", max_batch=1000, tile=32,
                           interpret=True)
    reqs = _mixed_requests(ms=(5, 40), reps=2)
    futs = [sched.submit(*r) for r in reqs]
    sched.flush()
    for (A, b, c), f in zip(reqs, futs):
        r = f.result(timeout=120.0)
        direct = get_solver(SolverSpec(
            backend="kernel", interpret=True)).solve(make_batch(A, b, c))
        assert bool(direct.feasible[0]) == r.feasible
        np.testing.assert_allclose(np.asarray(direct.x[0]), r.x,
                                   rtol=1e-5, atol=1e-5)


def test_infeasible_and_degenerate_roundtrip():
    sched = BatchScheduler(max_batch=1000, tile=8)
    rng = np.random.default_rng(7)
    theta = rng.uniform(0, 2 * np.pi, 6)
    A = np.stack([np.cos(theta), np.sin(theta)], -1).astype(np.float32)
    xstar = rng.uniform(-5, 5, 2)
    degenerate = (A, (A @ xstar).astype(np.float32),
                  np.array([1.0, 0.0], np.float32))
    infeasible = (np.array([[1.0, 0.0], [-1.0, 0.0]], np.float32),
                  np.array([-1.0, -1.0], np.float32),
                  np.array([1.0, 0.0], np.float32))
    fd = sched.submit(*degenerate)
    fi = sched.submit(*infeasible)
    sched.flush()
    assert fd.result().feasible
    np.testing.assert_allclose(fd.result().x, xstar, rtol=1e-4,
                               atol=1e-4)
    assert not fi.result().feasible


# -- executable cache ----------------------------------------------------

def test_cache_hit_accounting():
    sched = BatchScheduler(max_batch=8, tile=8)
    # all in one m-bucket (16) so each round is exactly one flush
    reqs = _mixed_requests(ms=(9, 10, 11, 12, 13, 14, 15, 16), reps=1)
    for round_ in range(3):
        for r in reqs:
            sched.submit(*r)  # 8th submit size-flushes each round
    assert sched.pending() == 0
    stats = sched.cache.stats()
    # identical traffic -> one spec: 1 miss, then hits
    assert stats["misses"] == 1 and stats["size"] == 1
    assert stats["hits"] == 2
    # a new shape bucket is a new executable
    big = _mixed_requests(ms=(200,) * 8, reps=1)
    for r in big:
        sched.submit(*r)
    stats = sched.cache.stats()
    assert stats["misses"] == 2 and stats["size"] == 2
    assert stats["hit_rate"] == pytest.approx(2 / 4)


def test_bogus_method_rejected_at_construction():
    """Stringly-typed dispatch used to fail only at flush time; the
    SolverSpec validates when the scheduler is built."""
    with pytest.raises(ValueError):
        BatchScheduler(method="bogus", max_batch=1000, tile=8)
    with pytest.raises(ValueError):
        SolverSpec(backend="bogus")


def _failing_builder(spec):
    raise ValueError(f"executable build refused for {spec.bucket_m}")


def test_solver_error_propagates_to_futures():
    sched = BatchScheduler(max_batch=1000, tile=8)
    sched.cache = ExecutableCache(_failing_builder)
    f = sched.submit(*_mixed_requests(ms=(5,), reps=1)[0])
    with pytest.raises(ValueError):
        sched.flush()
    assert isinstance(f.exception(timeout=1.0), ValueError)


def test_timer_thread_survives_solver_error():
    """A failing wait-triggered flush must not kill the flush thread:
    later requests still get flushed (and their futures resolved)."""
    sched = BatchScheduler(max_batch=1000, max_wait_s=0.01, tile=8)
    sched.cache = ExecutableCache(_failing_builder)
    sched.start()
    try:
        req = _mixed_requests(ms=(5,), reps=1)[0]
        f1 = sched.submit(*req)
        assert isinstance(f1.exception(timeout=5.0), ValueError)
        f2 = sched.submit(*req)  # thread must still be alive to flush
        assert isinstance(f2.exception(timeout=5.0), ValueError)
    finally:
        sched._stop.set()
        sched._thread.join()
        sched._thread = None
    # the swallowed-and-counted timer errors are surfaced, not silent
    snap = sched.metrics.snapshot()
    assert snap["errors"].get("timer_flush", 0) >= 1
    assert "errors" in snap and "timer_flush" in \
        sched.metrics.format_report()


def _selective_failing_builder(fail_bucket_m):
    """Builder failing only for one m-bucket; others build for real."""
    def build(spec):
        if spec.bucket_m == fail_bucket_m:
            raise ValueError(f"injected failure for bucket "
                             f"{spec.bucket_m}")
        return build_executable(spec, jax.devices())
    return build


@pytest.mark.parametrize("pipeline", [True, False])
def test_multi_bucket_flush_failure_isolated(pipeline):
    """One bucket's failing solve must not orphan the other buckets'
    futures: every future of the flush resolves (result or exception)
    and the first error still reaches the flush() caller.  (fuse=False:
    with fusing the same isolation holds per flush *unit* — covered by
    the fused-flush tests.)"""
    sched = BatchScheduler(max_batch=1000, tile=8, pipeline=pipeline,
                           fuse=False)
    sched.cache = ExecutableCache(_selective_failing_builder(16))
    # three buckets, dict order 8 -> 16 -> 128: the failure sits in the
    # middle so both an earlier and a later bucket must survive it
    f_ok1 = sched.submit(*_mixed_requests(ms=(5,), reps=1)[0])    # 8
    f_bad = sched.submit(*_mixed_requests(ms=(9,), reps=1)[0])    # 16
    f_ok2 = sched.submit(*_mixed_requests(ms=(70,), reps=1)[0])   # 128
    with pytest.raises(ValueError, match="injected failure"):
        sched.flush()
    assert f_ok1.result(timeout=60.0).feasible in (True, False)
    assert f_ok2.result(timeout=60.0).feasible in (True, False)
    assert isinstance(f_bad.exception(timeout=60.0), ValueError)


def test_close_refuses_new_submits_and_resolves_queued():
    sched = BatchScheduler(max_batch=1000, tile=8)
    futs = [sched.submit(*r) for r in _mixed_requests(reps=1)]
    sched.close()
    for f in futs:
        assert f.result(timeout=60.0) is not None
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(*_mixed_requests(ms=(5,), reps=1)[0])
    # close is idempotent
    sched.close()


def test_close_vs_submit_race_never_orphans():
    """Hammer close() against concurrent submit(): every future handed
    out must resolve — a submit either loses the race (raises) or its
    request is caught by the final flush.  Regression test for the
    pre-fix ordering where `_closed` was set only *after* the final
    flush, so a request could enqueue with no flusher left alive."""
    req = _mixed_requests(ms=(9,), reps=1)[0]
    for _ in range(5):
        sched = BatchScheduler(max_batch=8, max_wait_s=0.001, tile=8)
        sched.start()
        futs, lock = [], threading.Lock()

        def submitter():
            while True:
                try:
                    f = sched.submit(*req)
                except RuntimeError:
                    return
                with lock:
                    futs.append(f)

        threads = [threading.Thread(target=submitter) for _ in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.02)
        sched.close()
        for t in threads:
            t.join(timeout=60.0)
            assert not t.is_alive(), "submitter never saw the close"
        for f in futs:
            f.result(timeout=60.0)   # must never hang: no orphans


# -- the pipelined serve loop --------------------------------------------


class _SlowCompleteExec:
    """Wrap a real executable so completion takes a deterministic
    minimum time — makes overlap/backpressure observable on CPU."""

    def __init__(self, inner, delay_s):
        self.inner = inner
        self.delay_s = delay_s

    def dispatch(self, L, c, mv):
        return self.inner.dispatch(L, c, mv)

    def complete(self, handle):
        time.sleep(self.delay_s)
        return self.inner.complete(handle)


class _AuditPool(_FlushBufferPool):
    """Pool that records lease/release interleaving: a buffer set may
    never be leased out twice without an intervening release."""

    def __init__(self):
        super().__init__()
        self._audit_lock = threading.Lock()
        self._out = set()
        self.max_outstanding = 0
        self.violations = 0

    def lease(self, b_pad, bm, dtype):
        key, bufs = super().lease(b_pad, bm, dtype)
        with self._audit_lock:
            bid = id(bufs[0])
            if bid in self._out:
                self.violations += 1
            self._out.add(bid)
            self.max_outstanding = max(self.max_outstanding,
                                       len(self._out))
        return key, bufs

    def release(self, key, bufs):
        with self._audit_lock:
            self._out.discard(id(bufs[0]))
        super().release(key, bufs)


def test_pipelined_overlap_backpressure_and_buffers():
    """The tentpole contract: with a slow solve, (a) >= 2 flushes are
    concurrently in flight but never more than max_inflight, (b) a
    leased buffer set is never reused while its flush is in flight,
    (c) results still scatter in submission order and match the direct
    solver."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=4, max_inflight=2)
    sched.cache = ExecutableCache(
        lambda s: _SlowCompleteExec(build_executable(s, jax.devices()),
                                    0.05))
    sched.buffers = _AuditPool()
    reqs = _mixed_requests(ms=(9, 10, 11, 12), reps=4)  # one bucket (16)
    futs = [sched.submit(*r) for r in reqs]             # 4 size flushes
    results = [f.result(timeout=120.0) for f in futs]
    sched.drain()
    snap = sched.metrics.snapshot()
    # (a) overlap happened and the depth bound held
    assert snap["inflight_max"] == 2, snap
    assert snap["overlapped_dispatches"] >= 1
    assert snap["inflight_now"] == 0
    assert snap["n_dispatched"] == 4
    # the device-idle estimate exists and is bounded by the elapsed time
    assert 0.0 <= snap["device_idle_s_est"] <= snap["elapsed_s"] + 1.0
    # (b) the buffer audit: concurrent flushes used disjoint buffer
    # sets (>= 2 live at once), never a leased one
    assert sched.buffers.violations == 0
    assert sched.buffers.max_outstanding >= 2
    assert sched.buffers.lease_count == 4
    # assembly overlapping in-flight solves => more than one set was
    # allocated, but backpressure bounds it to max_inflight + 1
    assert 2 <= sched.buffers.alloc_count <= 3
    # (c) submission-order scatter, bit-identical to the direct solver
    solver = spec.build()
    for (A, b, c), r in zip(reqs, results):
        direct = solver.solve(make_batch(A, b, c))
        assert bool(direct.feasible[0]) == r.feasible
        np.testing.assert_array_equal(np.asarray(direct.x[0]), r.x)


def test_pipelined_solve_failure_reaches_futures_not_flush_caller():
    """A failure surfacing at completion (after dispatch) lands on the
    flush's futures and the error counter — flush() itself already
    returned."""
    class _FailingComplete:
        def dispatch(self, L, c, mv):
            return "handle"

        def complete(self, handle):
            raise RuntimeError("injected completion failure")

    sched = BatchScheduler(max_batch=1000, tile=8)
    sched.cache = ExecutableCache(lambda s: _FailingComplete())
    f = sched.submit(*_mixed_requests(ms=(5,), reps=1)[0])
    sched.flush()          # dispatch succeeds; no raise here
    assert isinstance(f.exception(timeout=60.0), RuntimeError)
    sched.drain()
    assert sched.metrics.snapshot()["errors"].get("solve", 0) == 1


def test_as_executable_adapts_plain_callables():
    calls = []

    def sync_fn(L, c, mv):
        calls.append(L.shape)
        return "x", "feas"

    exe = as_executable(sync_fn)
    assert exe.complete(exe.dispatch(np.zeros((2, 4, 8)), None, None)) \
        == ("x", "feas")
    assert calls == [(2, 4, 8)]
    # real executables and test doubles pass through unchanged
    assert as_executable(exe) is exe


# -- metrics -------------------------------------------------------------

def test_metrics_percentiles():
    m = ServeMetrics()
    for v in range(1, 101):
        m.record_latency(v / 1000.0)
    assert m.percentile(50) == pytest.approx(0.0505)
    assert m.percentile(99) == pytest.approx(0.09901)
    m.record_flush(n_real=3, b_pad=8, bucket_m=128, sum_m=30,
                   solve_seconds=0.01, reason="manual")
    s = m.snapshot()
    assert s["padding_waste_problems"] == pytest.approx(5 / 8)
    assert s["padding_waste_cells"] == pytest.approx(1 - 30 / (8 * 128))


def test_latency_reservoir_stays_uniform():
    """Past capacity the reservoir keeps sampling (deterministically,
    no `random` on the hot path) instead of freezing on the first k
    samples — late-run latencies must stay represented."""
    m = ServeMetrics(max_latency_samples=100)
    n = 5000
    for v in range(n):
        m.record_latency(float(v))
    s = m.snapshot()
    assert s["latency_seen"] == n
    assert s["latency_samples"] == 100
    kept = sorted(m._latencies)
    # uniform reservoir: the second half of the run is represented
    # (a capped list would hold only 0..99, median would be ~50)
    assert sum(1 for v in kept if v >= n / 2) >= 20
    assert m.percentile(50.0) > n * 0.2
    # deterministic: same stream -> same reservoir
    m2 = ServeMetrics(max_latency_samples=100)
    for v in range(n):
        m2.record_latency(float(v))
    assert m2._latencies == m._latencies
    # the report names the sampling so percentiles aren't over-read
    assert "reservoir: 100 of 5000" in m.format_report()


def test_error_counter_and_one_time_warning():
    m = ServeMetrics()
    with pytest.warns(RuntimeWarning, match="broken thing"):
        m.record_error("timer_flush", warn="broken thing happened")
    # second error of the same kind counts but does not warn again
    m.record_error("timer_flush", warn="broken thing happened")
    m.record_error("solve")
    s = m.snapshot()
    assert s["errors"] == {"timer_flush": 2, "solve": 1}
    assert "timer_flush=2" in m.format_report()


def test_bench_traffic_deterministic():
    a = make_request(BenchConfig(seed=3), 5)
    b = make_request(BenchConfig(seed=3), 5)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    assert a[3] == b[3]


def test_bench_smoke_tiny():
    cfg = BenchConfig(requests=24, rate=1e6, m_max=64, max_batch=8,
                      max_wait_s=0.005, tile=8, check=3, warmup=False)
    snap, sched = run_traffic(cfg, quiet=True)
    assert snap["n_solved"] == 24
    assert snap["cache"]["misses"] >= 1
    assert 0.0 <= snap["padding_waste_cells"] < 1.0
    assert np.isfinite(snap["latency_p99_ms"])
    # pipelined loop fully quiesced, every dispatch completed
    assert snap["inflight_now"] == 0
    assert snap["n_dispatched"] == snap["n_flushes"]
    assert snap["errors"] == {}


# -- mesh layout planning (pure layout algebra, no devices needed) -------

def test_plan_layout_even_split():
    lay = plan_layout(64, 16, 4)
    assert lay.shards == (16, 16, 16, 16)
    assert lay.b_pad == 64 and lay.used_devices == 4
    (g,) = lay.groups
    assert g.sizes == (4, 16) and g.strides == (16, 1)
    assert g.offset == 0 and lay.n_launches == 1


def test_plan_layout_uneven_two_groups():
    # 5 tiles dealt over 4 devices: q/q+1 with the larger shard first,
    # so the launch plan is exactly two contiguous groups
    lay = plan_layout(80, 16, 4)
    assert lay.shards == (32, 16, 16, 16)
    g0, g1 = lay.groups
    assert g0 == LaunchGroup(start=0, n_devices=1, rows_per_device=32,
                             offset=0)
    assert g1 == LaunchGroup(start=1, n_devices=3, rows_per_device=16,
                             offset=32)
    # the layout algebra maps (device, local row) -> global row
    assert lay.global_row(0, 31) == 31
    assert lay.global_row(1, 0) == 32
    assert lay.global_row(3, 15) == 79
    with pytest.raises(IndexError):
        lay.global_row(1, 16)


def test_plan_layout_underfull_skips_devices():
    # an underfull flush simply doesn't use trailing devices (pmap
    # would instead pad the batch 4x to occupy them)
    lay = plan_layout(16, 16, 4)
    assert lay.shards == (16, 0, 0, 0)
    assert lay.used_devices == 1 and lay.n_launches == 1
    assert lay.groups[0].rows == 16


def test_plan_layout_prime_rows_pad_to_tile_only():
    # the planner owns padding: prime row counts round up to whole
    # kernel tiles, never to tile * n_devices blocks
    lay = plan_layout(37, 16, 4)
    assert lay.b_pad == 48 and lay.shards == (16, 16, 16, 0)
    assert lay.pad_rows(37) == 11
    assert "48 rows = [16 16 16 0] @ tile=16, 1 launch" == lay.describe()


def test_plan_layout_invariants_sweep():
    # every (rows, devices) combination: padding bounded by one tile,
    # at most two launches, groups cover the padded batch exactly
    for rows in range(1, 161, 7):
        for n_dev in (1, 2, 3, 4, 5, 8):
            lay = plan_layout(rows, 8, n_dev)
            assert rows <= lay.b_pad < rows + 8
            assert lay.n_launches <= 2
            assert sum(g.rows for g in lay.groups) == lay.b_pad
            assert lay.offsets[0] == 0
            for g in lay.groups:
                assert g.rows_per_device % 8 == 0


def test_plan_layout_and_mesh_layout_validation():
    with pytest.raises(ValueError):
        plan_layout(0, 16, 4)
    with pytest.raises(ValueError):
        plan_layout(16, 0, 4)
    with pytest.raises(ValueError):
        plan_layout(16, 16, 0)
    with pytest.raises(ValueError):
        MeshLayout(shards=(15,), tile=16)   # not a tile multiple
    with pytest.raises(ValueError):
        MeshLayout(shards=(0, 0), tile=16)  # carries zero rows
    with pytest.raises(ValueError):
        MeshLayout(shards=(), tile=16)


# -- cross-bucket fused flush units --------------------------------------

def _direct_solve(spec, A, b, c):
    return spec.build().solve(make_batch(A, b, c))


def test_fused_flush_scatter_routing():
    """A manual flush over several underfull buckets fuses them into
    shared launches; every request's result still lands on its own
    future, bit-identical to a direct solve."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=64, max_wait_s=60.0)
    assert sched.fuse   # mesh sharding fuses by default
    reqs = _mixed_requests(ms=(3, 5, 12, 14, 30, 60), reps=2)
    futs = [sched.submit(*r) for r in reqs]
    sched.flush()
    results = [f.result(timeout=120.0) for f in futs]
    sched.drain()
    for (A, b, c), r in zip(reqs, results):
        d = _direct_solve(spec, A, b, c)
        assert bool(d.feasible[0]) == r.feasible
        np.testing.assert_array_equal(np.asarray(d.x[0]), r.x)
    snap = sched.metrics.snapshot()
    # buckets 8/16/32/64 fused into one unit (m spread 8 <= ratio)
    assert snap["flush_reasons"] == {"fused": 1}
    assert snap["fused_flushes"] == 1
    assert snap["fused_buckets"] == 4
    assert snap["launches_total"] >= 1
    sched.close()


def test_fused_joint_fill_submit_trigger():
    """Buckets that are individually under max_batch but jointly fill a
    launch flush at submit time — no wait, no manual flush."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=8, max_wait_s=60.0)
    reqs = (_mixed_requests(ms=(5,), reps=4)
            + _mixed_requests(seed=1, ms=(12,), reps=4))
    futs = [sched.submit(*r) for r in reqs]
    # the 8th submit crossed the joint-fill threshold: results arrive
    # without any flush() call or wait-trigger tick
    results = [f.result(timeout=120.0) for f in futs]
    sched.drain()
    for (A, b, c), r in zip(reqs, results):
        d = _direct_solve(spec, A, b, c)
        assert bool(d.feasible[0]) == r.feasible
        np.testing.assert_array_equal(np.asarray(d.x[0]), r.x)
    snap = sched.metrics.snapshot()
    assert snap["flush_reasons"].get("fused") == 1
    assert snap["fused_buckets"] == 2
    assert sched.pending() == 0
    sched.close()


def test_fuse_respects_m_ratio_and_disable():
    """Buckets whose m_pad spread exceeds fuse_max_m_ratio never share
    a unit, and fuse=False restores strict per-bucket flushes."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=64, max_wait_s=60.0,
                           fuse_max_m_ratio=2.0)
    futs = [sched.submit(*r) for r in
            _mixed_requests(ms=(5, 12, 100), reps=1)]  # buckets 8,16,128
    sched.flush()
    for f in futs:
        f.result(timeout=120.0)
    sched.drain()
    snap = sched.metrics.snapshot()
    # 8 and 16 fuse (ratio 2), 128 flushes alone
    assert snap["fused_flushes"] == 1 and snap["fused_buckets"] == 2
    assert snap["n_flushes"] == 2
    sched.close()

    nofuse = BatchScheduler(spec, max_batch=64, max_wait_s=60.0,
                            fuse=False)
    futs = [nofuse.submit(*r) for r in
            _mixed_requests(ms=(5, 12, 30), reps=1)]
    nofuse.flush()
    for f in futs:
        f.result(timeout=120.0)
    nofuse.drain()
    snap = nofuse.metrics.snapshot()
    assert snap["fused_flushes"] == 0
    assert snap["n_flushes"] == 3
    assert snap["flush_reasons"] == {"manual": 3}
    nofuse.close()


def test_fused_policy_allow_fuse_veto():
    """A 3-tuple bucket policy's allow_fuse=False keeps that bucket out
    of fused units while others still fuse."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=64, max_wait_s=60.0)
    sched.set_bucket_policy(
        lambda bm: (64, 60.0, bm != 8))   # bucket 8 must fly solo
    futs = [sched.submit(*r) for r in
            _mixed_requests(ms=(5, 12, 30), reps=1)]
    sched.flush()
    for f in futs:
        f.result(timeout=120.0)
    sched.drain()
    snap = sched.metrics.snapshot()
    # 16 + 32 fused; 8 flushed alone despite being fusable by ratio
    assert snap["n_flushes"] == 2
    assert snap["fused_flushes"] == 1 and snap["fused_buckets"] == 2
    sched.close()


def test_fused_flush_buffer_pool_audit():
    """Fused units lease/release flush buffers with the same
    no-double-lease discipline as plain flushes."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=16, max_wait_s=60.0)
    sched.buffers = _AuditPool()
    futs = []
    for rep in range(3):
        futs += [sched.submit(*r) for r in
                 _mixed_requests(seed=rep, ms=(3, 5, 12, 14), reps=2)]
        sched.flush()
    for f in futs:
        f.result(timeout=120.0)
    sched.drain()
    assert sched.buffers.violations == 0
    assert sched.buffers.lease_count == \
        sched.metrics.snapshot()["n_flushes"]
    assert sched.metrics.snapshot()["fused_flushes"] >= 1
    sched.close()


def test_pmap_escape_hatch_roundtrip():
    """sharding="pmap" stays green: the legacy path solves the same
    traffic bit-identically (single local device here; CI re-runs this
    under 4 forced host devices)."""
    spec = SolverSpec(backend="rgb", tile=8)
    sched = BatchScheduler(spec, max_batch=1000, sharding="pmap")
    assert not sched.fuse   # pmap's even split predates fused units
    assert sched.batch_unit == 8 * sched.n_devices
    reqs = _mixed_requests(ms=(3, 8, 37, 130), reps=2)
    futs = [sched.submit(*r) for r in reqs]
    sched.flush()
    for (A, b, c), f in zip(reqs, futs):
        r = f.result(timeout=120.0)
        d = _direct_solve(spec, A, b, c)
        assert bool(d.feasible[0]) == r.feasible
        np.testing.assert_array_equal(np.asarray(d.x[0]), r.x)
    sched.drain()
    assert sched.metrics.snapshot()["fused_flushes"] == 0
    sched.close()
    with pytest.raises(ValueError, match="sharding"):
        BatchScheduler(spec, sharding="banana")


# -- multi-device sharding (out-of-process, forced host devices) ---------

def test_sharded_matches_single_device(multidevice):
    code = """
import jax, numpy as np
from repro.core import make_batch
from repro.solver import SolverSpec, get_solver
from repro.serve_lp import BatchScheduler
assert len(jax.devices()) == 4
rng = np.random.default_rng(0)
reqs = []
for m in (3, 8, 40, 130) * 4:
    theta = rng.uniform(0, 2 * np.pi, m)
    A = np.stack([np.cos(theta), np.sin(theta)], -1).astype(np.float32)
    b = (A @ rng.uniform(-5, 5, 2) + rng.uniform(0.1, 2, m)).astype(
        np.float32)
    c = np.array([1.0, 0.5], np.float32)
    reqs.append((A, b, c))
sched = BatchScheduler(method="rgb", max_batch=1000, tile=8)
futs = [sched.submit(*r) for r in reqs]
sched.flush()
for (A, b, c), f in zip(reqs, futs):
    r = f.result(timeout=60.0)
    d = get_solver(SolverSpec(backend="rgb", tile=8,
                              chunk=0)).solve(make_batch(A, b, c))
    assert bool(d.feasible[0]) == r.feasible
    np.testing.assert_allclose(np.asarray(d.x[0]), r.x, rtol=1e-5,
                               atol=1e-5)
print("sharded-ok", len(reqs))
"""
    out = multidevice(code, n_devices=4)
    assert "sharded-ok 16" in out


def test_mesh_vs_pmap_bit_identity(multidevice):
    """The tentpole equivalence claim: over an adversarial packed batch
    (ragged + infeasible + degenerate rows), the shard_map mesh path,
    the legacy pmap path and a plain single-launch jit produce
    bit-identical results on 4 devices."""
    code = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.core import concat_batches, make_batch, ragged_feasible_lp
from repro.core.packed import pack
from repro.serve_lp import ExecSpec, SolverSpec, build_executable
from repro.solver import solve_with_spec
assert len(jax.devices()) == 4
rng = np.random.default_rng(7)
batches = [ragged_feasible_lp(jax.random.key(0), 20, 24, m_min=2)]
# infeasible rows: two opposed halfplanes
A = np.array([[1.0, 0.0], [-1.0, 0.0], [0.0, 1.0]], np.float32)
b = np.array([-1.0, -1.0, 5.0], np.float32)
batches.append(make_batch(A, b, np.array([1.0, 0.5], np.float32)))
# degenerate: every constraint tight at one point
th = rng.uniform(0, 2 * np.pi, 24).astype(np.float32)
Ad = np.stack([np.cos(th), np.sin(th)], -1).astype(np.float32)
x0 = rng.uniform(-5, 5, 2).astype(np.float32)
batches.append(make_batch(Ad, Ad @ x0, np.array([0.0, 1.0], np.float32)))
lp = concat_batches(batches)
pb = pack(lp, m_pad=32)
B = int(pb.L.shape[0])
assert B == 22
# pad to the pmap-legal rung so all three paths take identical input
pad = 64 - B
Lp = np.zeros((pad, 4, 32), np.float32); Lp[:, 2, :] = 1e9
L = np.concatenate([np.asarray(pb.L), Lp])
c = np.concatenate([np.asarray(pb.c),
                    np.tile(np.array([[1.0, 0.0]], np.float32),
                            (pad, 1))])
mv = np.concatenate([np.asarray(pb.m_valid),
                     np.zeros((pad, 1), np.int32)])
solver = SolverSpec(backend="rgb", tile=16)
mesh_exe = build_executable(
    ExecSpec(bucket_m=32, b_pad=64, solver=solver, n_devices=4),
    jax.devices())
pmap_exe = build_executable(
    ExecSpec(bucket_m=32, b_pad=64, solver=solver, sharding="pmap",
             n_devices=4),
    jax.devices())
assert mesh_exe.shards == (16, 16, 16, 16)
assert pmap_exe.shards == (16, 16, 16, 16)
xm, fm = mesh_exe(L, c, mv)
xp, fp = pmap_exe(L, c, mv)
from repro.core.packed import PackedLPBatch
ref = solve_with_spec(dataclasses.replace(solver),
                      PackedLPBatch(L=jnp.asarray(L), c=jnp.asarray(c),
                                    m_valid=jnp.asarray(mv)))
np.testing.assert_array_equal(xm, xp)
np.testing.assert_array_equal(fm, fp)
np.testing.assert_array_equal(xm, np.asarray(ref.x))
np.testing.assert_array_equal(fm, np.asarray(ref.feasible))
assert fm[:B].sum() == 21   # the one infeasible row stayed infeasible
print("identity-ok", B)
"""
    out = multidevice(code, n_devices=4)
    assert "identity-ok 22" in out


def test_uneven_shards_match_reference(multidevice):
    """5 tiles over 4 devices: a two-group uneven layout (32+16+16+16)
    solves to exactly what a single plain-jit launch produces."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import ragged_feasible_lp
from repro.core.packed import pack
from repro.serve_lp import ExecSpec, SolverSpec, build_executable
from repro.solver import solve_with_spec
assert len(jax.devices()) == 4
lp = ragged_feasible_lp(jax.random.key(5), 80, 24, m_min=2)
pb = pack(lp, m_pad=32)
L, c, mv = (np.asarray(pb.L), np.asarray(pb.c), np.asarray(pb.m_valid))
solver = SolverSpec(backend="rgb", tile=16)
exe = build_executable(
    ExecSpec(bucket_m=32, b_pad=80, solver=solver, n_devices=4),
    jax.devices())
assert exe.layout.shards == (32, 16, 16, 16)
assert exe.n_launches == 2
x, feas = exe(L, c, mv)
from repro.core.packed import PackedLPBatch
ref = solve_with_spec(solver, PackedLPBatch(
    L=jnp.asarray(L), c=jnp.asarray(c), m_valid=jnp.asarray(mv)))
np.testing.assert_array_equal(x, np.asarray(ref.x))
np.testing.assert_array_equal(feas, np.asarray(ref.feasible))
assert feas.all() and x.shape == (80, 2)
print("uneven-ok", exe.layout.describe())
"""
    out = multidevice(code, n_devices=4)
    assert "uneven-ok 80 rows = [32 16 16 16] @ tile=16, 2 launches" \
        in out


def test_prime_sized_flush_on_four_devices(multidevice):
    """Regression for the silent whole-shard requirement: a prime-sized
    flush (b_pad=37) on 4 devices builds, pads to whole tiles inside
    the executable, and returns exactly 37 trimmed rows."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import ragged_feasible_lp
from repro.core.packed import pack
from repro.serve_lp import ExecSpec, SolverSpec, build_executable
from repro.solver import solve_with_spec
assert len(jax.devices()) == 4
lp = ragged_feasible_lp(jax.random.key(11), 37, 24, m_min=2)
pb = pack(lp, m_pad=32)
L, c, mv = (np.asarray(pb.L), np.asarray(pb.c), np.asarray(pb.m_valid))
solver = SolverSpec(backend="rgb", tile=16)
exe = build_executable(
    ExecSpec(bucket_m=32, b_pad=37, solver=solver, n_devices=4),
    jax.devices())
# ceil(37/16)=3 tiles: one per device, fourth device unused, 11 pad
# rows -- not the 27 pad rows a whole 16*4 block would cost
assert exe.layout.shards == (16, 16, 16, 0)
assert exe.layout.pad_rows(37) == 11
x, feas = exe(L, c, mv)
assert x.shape == (37, 2) and feas.shape == (37,)
from repro.core.packed import PackedLPBatch
ref = solve_with_spec(solver, PackedLPBatch(
    L=jnp.asarray(L), c=jnp.asarray(c), m_valid=jnp.asarray(mv)))
np.testing.assert_array_equal(x, np.asarray(ref.x))
np.testing.assert_array_equal(feas, np.asarray(ref.feasible))
assert feas.all()
print("prime-ok", int(feas.sum()))
"""
    out = multidevice(code, n_devices=4)
    assert "prime-ok 37" in out


def test_fused_scheduler_multidevice(multidevice):
    """End-to-end fused serving on a real 4-device mesh: heterogeneous
    underfull buckets fuse into shared launches, results stay
    bit-identical to direct solves, and unused devices carry no rows."""
    code = """
import jax, numpy as np
from repro.core import make_batch
from repro.serve_lp import BatchScheduler, SolverSpec
from repro.solver import get_solver
assert len(jax.devices()) == 4
spec = SolverSpec(backend="rgb", tile=8)
sched = BatchScheduler(spec, max_batch=64, max_wait_s=60.0)
rng = np.random.default_rng(2)
reqs = []
for m in (3, 5, 12, 14, 30, 60) * 2:
    theta = rng.uniform(0, 2 * np.pi, m)
    A = np.stack([np.cos(theta), np.sin(theta)], -1).astype(np.float32)
    b = (A @ rng.uniform(-5, 5, 2) + rng.uniform(0.1, 2, m)).astype(
        np.float32)
    reqs.append((A, b, np.array([1.0, 0.5], np.float32)))
futs = [sched.submit(*r) for r in reqs]
sched.flush()
solver = get_solver(spec)
for (A, b, c), f in zip(reqs, futs):
    r = f.result(timeout=120.0)
    d = solver.solve(make_batch(A, b, c))
    assert bool(d.feasible[0]) == r.feasible
    np.testing.assert_array_equal(np.asarray(d.x[0]), r.x)
sched.drain()
snap = sched.metrics.snapshot()
assert snap["fused_flushes"] == 1 and snap["fused_buckets"] == 4
assert snap["launches_total"] >= 1
assert len(snap["rows_per_device"]) == 4
# 12 fused reqs pad to b_pad=16 (two 8-row tiles), spread over two
# devices; the other two devices carry no rows
assert sum(snap["rows_per_device"]) == 16
assert snap["rows_per_device"].count(0) == 2
print("fused-mesh-ok", snap["rows_per_device"])
"""
    out = multidevice(code, n_devices=4)
    assert "fused-mesh-ok" in out
