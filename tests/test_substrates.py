"""Data pipeline, checkpointing, optimizer and fault-tolerance tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch.elastic import Heartbeat, StragglerMonitor
from repro.optim import (AdamW, apply_updates, dequantize_int8,
                         lp_constrain_updates, quantize_int8,
                         sync_duplicated_grads)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    s1 = TokenSource(cfg)
    s2 = TokenSource(cfg)
    for step in (0, 5, 1000):
        a = s1.global_batch(step)
        b = s2.global_batch(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_data_host_sharding_partitions_global():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=12, seed=1)
    src = TokenSource(cfg)
    g = src.global_batch(7)
    parts = [src.host_batch(7, h, 4) for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), g["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2, seed=0)
    b = TokenSource(cfg).global_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_file_source(tmp_path):
    toks = np.arange(10000, dtype=np.uint32)
    p = tmp_path / "toks.bin"
    toks.tofile(p)
    cfg = DataConfig(vocab=50000, seq_len=8, global_batch=2,
                     source="file", path=str(p))
    b0 = TokenSource(cfg).global_batch(0)
    assert b0["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b0["tokens"][0, :3], [0, 1, 2])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((2,), jnp.int32)}}


def test_ckpt_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(5, tree, extra={"next_step": 5}, blocking=True)
    out, extra = ck.load(jax.eval_shape(lambda: tree))
    assert extra["next_step"] == 5
    for k, a, b in (("a", tree["a"], out["a"]),
                    ("c", tree["b"]["c"], out["b"]["c"]),
                    ("d", tree["b"]["d"], out["b"]["d"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype, k


def test_ckpt_latest_pointer_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), blocking=True)
    assert ck.latest_step() == 4
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_ckpt_crash_safety(tmp_path):
    """A stale .tmp dir from a crashed save must not break the next one."""
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), blocking=True)
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "junk.npy").write_bytes(b"xx")
    ck.save(2, _tree(), blocking=True)
    assert ck.latest_step() == 2
    out, _ = ck.load(jax.eval_shape(lambda: _tree()))
    assert out["a"].shape == (2, 3)


def test_ckpt_async(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(7, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 7


def test_ckpt_namedtuple_state(tmp_path):
    opt = AdamW()
    params = {"w": jnp.ones((3, 3))}
    state = opt.init(params)
    ck = Checkpointer(tmp_path)
    ck.save(1, (params, state), blocking=True)
    (p2, s2), _ = ck.load(jax.eval_shape(lambda: (params, state)))
    assert type(s2).__name__ == "AdamWState"
    np.testing.assert_array_equal(np.asarray(s2.m["w"]),
                                  np.asarray(state.m["w"]))


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sync_duplicated_grads():
    hd = 4
    g = {"blocks": {"wk": jnp.arange(2 * 3 * 16, dtype=jnp.float32)
                    .reshape(2, 3, 16)}}
    out = sync_duplicated_grads(g, {"blocks/wk": 2}, hd)
    w = np.asarray(out["blocks"]["wk"]).reshape(2, 3, 2, 2, hd)
    np.testing.assert_allclose(w[..., 0, :], w[..., 1, :])
    # averaging preserves the mean
    np.testing.assert_allclose(np.asarray(out["blocks"]["wk"]).sum(),
                               np.asarray(g["blocks"]["wk"]).sum(),
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_int8_quantization_error_bounded(seed):
    g = jax.random.normal(jax.random.key(seed), (128,)) * 10
    q, s = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the SUM of compressed steps converges to the
    sum of true gradients (bias-free to first order)."""
    rng = np.random.default_rng(0)
    true = rng.standard_normal((64,)).astype(np.float32)
    e = np.zeros_like(true)
    acc = np.zeros_like(true)
    for _ in range(300):
        g32 = true + e
        amax = np.abs(g32).max()
        s = amax / 127.0 if amax > 0 else 1.0
        q = np.clip(np.round(g32 / s), -127, 127)
        e = g32 - q * s
        acc += q * s
    np.testing.assert_allclose(acc / 300, true, atol=1e-2)


def test_lp_constrained_updates_shrink_when_binding():
    """Huge proposed update vs tiny params -> trust region must bind and
    scale the update down (s1 < 1)."""
    params = {"w": jnp.ones((8,)) * 1e-3}
    updates = {"w": jnp.ones((8,)) * 10.0}
    grads = {"w": -jnp.ones((8,))}  # descent direction opposite to update?
    momenta = {"w": jnp.zeros((8,))}
    new, s1 = lp_constrain_updates(updates, grads, momenta, params,
                                   delta=0.05)
    assert float(s1) < 0.05
    assert float(jnp.abs(new["w"]).max()) < 1.0


def test_lp_constrained_updates_identity_when_safe():
    params = {"w": jnp.ones((8,)) * 100.0}
    updates = {"w": -jnp.ones((8,)) * 1e-3}
    grads = {"w": jnp.ones((8,))}
    momenta = {"w": jnp.ones((8,)) * 1e-6}
    new, s1 = lp_constrain_updates(updates, grads, momenta, params)
    assert float(s1) > 0.99
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.asarray(updates["w"]), rtol=0.15)


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat(tmp_path):
    hb = Heartbeat(tmp_path / "hb.json")
    assert hb.age() == float("inf")
    hb.beat(12)
    assert hb.age() < 5
    assert hb.read()["step"] == 12


def test_straggler_monitor():
    m = StragglerMonitor(threshold=3.0)
    for i in range(20):
        assert not m.record(i, 0.1)
    assert m.record(20, 1.0)  # 10x median
    assert m.flagged == [20]
    assert not m.record(21, 0.12)


def test_supervisor_restarts(tmp_path):
    """Driver that crashes once, then succeeds — supervisor must restart
    it and return 0."""
    import sys
    from repro.launch.elastic import Supervisor
    marker = tmp_path / "crashed_once"
    hb = tmp_path / "hb.json"
    code = (
        "import json,sys,time,os\n"
        f"m = {str(marker)!r}\n"
        f"hb = {str(hb)!r}\n"
        "open(hb,'w').write(json.dumps({'step':0,'t':time.time()}))\n"
        "if not os.path.exists(m):\n"
        "    open(m,'w').write('x'); sys.exit(3)\n"
        "sys.exit(0)\n")
    sup = Supervisor([sys.executable, "-c", code], hb,
                     stall_timeout=60, max_restarts=3, poll=0.1)
    assert sup.run() == 0
    assert sup.restarts == 1
