"""The unified SolverSpec/Solver front end: validation, hashability,
jit/vmap composability, backend resolution, and cross-backend
equivalence properties (Seidel exact backends and the first-order pdhg
backend)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (LPBatch, adversarial_lp, infeasible_lp,
                        make_batch, pack, ragged_feasible_lp,
                        random_feasible_lp, split_batch, unpack)
from repro.solver import Solver, SolverSpec, get_solver, solve_with_spec

TOL_5SIG = 5e-4  # the paper's 5-significant-figure comparison tolerance


# -- spec validation & hashing -------------------------------------------

def test_spec_validates_at_construction():
    SolverSpec()  # defaults are valid
    SolverSpec(backend="pdhg", iter_block=64, restart_period=0,
               tol=1e-6, max_iters=5000)  # pdhg knobs on pdhg: fine
    with pytest.raises(ValueError) as err:
        SolverSpec(backend="bogus")
    for name in ("naive", "rgb", "kernel", "pdhg", "auto"):
        assert name in str(err.value)  # error lists the full backend set
    with pytest.raises(ValueError):
        SolverSpec(tile=0)
    with pytest.raises(ValueError):
        SolverSpec(chunk=-1)
    with pytest.raises(ValueError):
        SolverSpec(M=0.0)
    with pytest.raises(ValueError):
        SolverSpec(M=-5.0)
    with pytest.raises(ValueError):
        SolverSpec(dtype="int32")
    with pytest.raises(ValueError):
        SolverSpec(seed="zero")
    # pdhg-only knobs are rejected on every other backend, auto included
    with pytest.raises(ValueError, match="pdhg-only"):
        SolverSpec(backend="rgb", tol=1e-6)
    with pytest.raises(ValueError, match="pdhg-only"):
        SolverSpec(backend="auto", iter_block=64)
    with pytest.raises(ValueError, match="pdhg-only"):
        SolverSpec(backend="kernel", restart_period=512, max_iters=100)
    # and value-validated on pdhg itself
    with pytest.raises(ValueError):
        SolverSpec(backend="pdhg", iter_block=0)
    with pytest.raises(ValueError):
        SolverSpec(backend="pdhg", restart_period=-1)
    with pytest.raises(ValueError):
        SolverSpec(backend="pdhg", tol=0.0)
    with pytest.raises(ValueError):
        SolverSpec(backend="pdhg", max_iters=0)


def test_spec_hashable_value_semantics():
    a = SolverSpec(backend="rgb", tile=8, chunk=64)
    b = SolverSpec(backend="rgb", tile=8, chunk=64)
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1
    assert a != SolverSpec(backend="rgb", tile=16, chunk=64)
    # M normalises to float so 1e4 (int or float) hash equal
    assert SolverSpec(M=10000) == SolverSpec(M=10000.0)


def test_spec_resolution():
    platform = jax.default_backend()
    r = SolverSpec(backend="auto").resolve()
    assert r.is_resolved
    assert r.backend == ("kernel" if platform == "tpu" else "rgb")
    k = SolverSpec(backend="kernel").resolve("cpu")
    assert k.interpret is True
    assert SolverSpec(backend="kernel").resolve("tpu").interpret is False
    # interpret is kernel-only and canonicalises away elsewhere
    assert SolverSpec(backend="rgb", interpret=True).resolve().interpret \
        is False
    # resolving an already-resolved spec is the identity
    assert r.resolve() is r
    # inert fields canonicalise: seed is pinned when shuffle=False, so
    # identical execution plans share one cache entry
    assert SolverSpec(backend="rgb", seed=5).resolve() == \
        SolverSpec(backend="rgb").resolve()
    assert SolverSpec(backend="rgb", seed=5, shuffle=True).resolve() != \
        SolverSpec(backend="rgb", shuffle=True).resolve()
    # unset launch geometry survives resolve() — it means "pick per
    # shape" and is pinned by resolve_for_shape (table, then heuristic)
    assert SolverSpec(backend="rgb").resolve().tile is None
    assert SolverSpec(backend="rgb").resolve().chunk is None
    assert SolverSpec(backend="kernel").resolve("cpu").tile is None


def test_spec_resolve_for_shape_heuristics():
    """With no tuning-table entry, resolve_for_shape pins exactly the
    pre-tuning heuristics; explicit values pass through untouched."""
    from repro.tune import TuningTable, use_table
    with use_table(TuningTable()):   # force table misses
        r = SolverSpec(backend="rgb").resolve_for_shape(21, 9)
        assert r.is_shape_resolved
        assert (r.tile, r.chunk) == (32, 0)
        k = SolverSpec(backend="kernel").resolve_for_shape(200, 64,
                                                           "cpu")
        assert k.tile is not None and k.chunk == 0
        e = SolverSpec(backend="rgb", tile=8,
                       chunk=64).resolve_for_shape(21, 9)
        assert (e.tile, e.chunk) == (8, 64)
        # resolving a shape-resolved spec is the identity
        assert r.resolve_for_shape(21, 9) is r


def test_float64_requires_x64():
    """dtype='float64' must refuse to run (not silently truncate to
    float32) unless jax x64 is enabled."""
    spec = SolverSpec(backend="rgb", dtype="float64")  # constructible
    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled in this process")
    with pytest.raises(ValueError, match="x64"):
        spec.build()
    with pytest.raises(ValueError, match="x64"):
        solve_with_spec(spec, random_feasible_lp(jax.random.key(0), 2, 4))


def test_spec_as_static_jit_argument():
    lp = random_feasible_lp(jax.random.key(0), 8, 12)
    calls = []

    def solve(spec, batch):
        calls.append(spec)
        return solve_with_spec(spec, batch)

    f = jax.jit(solve, static_argnums=0)
    s1 = f(SolverSpec(backend="rgb"), lp)
    s2 = f(SolverSpec(backend="rgb"), lp)  # equal spec: cache hit
    s3 = f(SolverSpec(backend="naive"), lp)
    assert len(calls) == 2  # one trace per distinct spec
    np.testing.assert_array_equal(np.asarray(s1.x), np.asarray(s2.x))
    np.testing.assert_allclose(np.asarray(s1.x), np.asarray(s3.x),
                               rtol=1e-5, atol=1e-5)


# -- Solver behaviour ----------------------------------------------------

def test_build_and_solve_paths_agree():
    lp = random_feasible_lp(jax.random.key(1), 16, 20)
    spec = SolverSpec(backend="rgb", tile=8)
    solver = spec.build()
    assert isinstance(solver, Solver)
    a = solver.solve(lp)
    b = solver(lp)                       # composable pure call
    c = jax.jit(solver)(lp)              # under an outer jit
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(c.x),
                               rtol=1e-6, atol=1e-6)


def test_solver_shape_cache():
    solver = SolverSpec(backend="rgb").build()
    solver.solve(random_feasible_lp(jax.random.key(0), 8, 12))
    solver.solve(random_feasible_lp(jax.random.key(1), 8, 12))
    assert solver.cache_info()["n_entries"] == 1
    solver.solve(random_feasible_lp(jax.random.key(2), 16, 12))
    assert solver.cache_info()["n_entries"] == 2
    solver.solve(random_feasible_lp(jax.random.key(3), 8, 12),
                 key=jax.random.key(0))  # keyed variant is its own entry
    assert solver.cache_info()["n_entries"] == 3


def test_get_solver_shares_instances():
    assert get_solver(SolverSpec(backend="rgb")) is \
        get_solver(SolverSpec(backend="rgb"))
    if jax.default_backend() != "tpu":
        # auto resolves to rgb off-TPU, landing on the same Solver
        assert get_solver(SolverSpec(backend="auto")) is \
            get_solver(SolverSpec(backend="rgb"))


def test_solve_one():
    lp = random_feasible_lp(jax.random.key(2), 4, 15)
    solver = SolverSpec(backend="rgb").build()
    batch_sol = solver.solve(lp)
    one = solver.solve_one(lp.A[2], lp.b[2], lp.c[2])
    assert one.x.shape == (2,)
    np.testing.assert_allclose(np.asarray(one.x),
                               np.asarray(batch_sol.x[2]),
                               rtol=1e-5, atol=1e-5)
    assert bool(one.feasible) == bool(batch_sol.feasible[2])


def test_shuffle_policy():
    lp = random_feasible_lp(jax.random.key(3), 8, 25)
    base = SolverSpec(backend="rgb")
    shuf = SolverSpec(backend="rgb", shuffle=True, seed=7)
    # spec-level shuffle == explicit per-call key with the same seed
    a = get_solver(shuf).solve(lp)
    b = get_solver(base).solve(lp, key=jax.random.key(7))
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    # a per-call key overrides the spec seed
    c = get_solver(shuf).solve(lp, key=jax.random.key(11))
    d = get_solver(base).solve(lp, key=jax.random.key(11))
    np.testing.assert_array_equal(np.asarray(c.x), np.asarray(d.x))
    # either way the optimum is order-invariant to tolerance
    np.testing.assert_allclose(np.asarray(a.objective),
                               np.asarray(c.objective),
                               rtol=TOL_5SIG, atol=TOL_5SIG)


def test_solver_vmap_composable():
    lp = random_feasible_lp(jax.random.key(4), 6, 10)
    stack = lambda a: jnp.stack([a, a])
    stacked = LPBatch(A=stack(lp.A), b=stack(lp.b), c=stack(lp.c),
                      m_valid=stack(lp.m_valid))
    for backend in ("naive", "rgb"):
        solver = get_solver(SolverSpec(backend=backend))
        flat = solver(lp)
        vs = jax.vmap(solver)(stacked)
        assert vs.x.shape == (2, 6, 2)
        np.testing.assert_allclose(np.asarray(vs.x[0]),
                                   np.asarray(flat.x),
                                   rtol=1e-5, atol=1e-5)


def test_dtype_cast_on_entry():
    lp = random_feasible_lp(jax.random.key(5), 4, 8)
    half = LPBatch(A=lp.A.astype(jnp.bfloat16),
                   b=lp.b.astype(jnp.bfloat16),
                   c=lp.c.astype(jnp.bfloat16), m_valid=lp.m_valid)
    sol = get_solver(SolverSpec(backend="rgb")).solve(half)
    assert sol.x.dtype == jnp.float32
    # A matching the spec dtype must not let mixed b/c leak through
    mixed = LPBatch(A=lp.A, b=lp.b.astype(jnp.bfloat16),
                    c=lp.c.astype(jnp.float16), m_valid=lp.m_valid)
    ref = get_solver(SolverSpec(backend="rgb")).solve(
        LPBatch(A=lp.A, b=lp.b.astype(jnp.bfloat16).astype(jnp.float32),
                c=lp.c.astype(jnp.float16).astype(jnp.float32),
                m_valid=lp.m_valid))
    got = get_solver(SolverSpec(backend="rgb")).solve(mixed)
    assert got.x.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got.x), np.asarray(ref.x))


# -- satellite regressions (core.lp) -------------------------------------

def test_make_batch_coerces_mismatched_dtypes():
    A = np.random.default_rng(0).normal(size=(3, 4, 2)).astype(np.float32)
    b = np.ones((3, 4), np.float64)      # mismatched: wider than A
    c = np.ones((3, 2), np.float16)      # mismatched: narrower than A
    batch = make_batch(A, b, c)
    assert batch.A.dtype == batch.b.dtype == batch.c.dtype == jnp.float32
    # integer A promotes to a float solve dtype
    bi = make_batch(np.ones((2, 3, 2), np.int32), np.ones((2, 3)),
                    np.ones((2, 2)))
    assert bi.A.dtype == jnp.float32
    # mixed inputs survive the full solve path
    sol = SolverSpec(backend="rgb").build().solve(batch)
    assert sol.x.dtype == jnp.float32


def test_split_batch_rejects_silent_remainder():
    lp = random_feasible_lp(jax.random.key(9), 8, 6)
    with pytest.raises(ValueError, match="allow_remainder"):
        split_batch(lp, [3, 2])          # 5 < 8: would drop 3 problems
    parts = split_batch(lp, [3, 2], allow_remainder=True)
    assert [p.batch for p in parts] == [3, 2]
    np.testing.assert_array_equal(np.asarray(parts[1].A),
                                  np.asarray(lp.A[3:5]))
    exact = split_batch(lp, [5, 3])      # exact cover still fine
    assert [p.batch for p in exact] == [5, 3]
    with pytest.raises(ValueError):
        split_batch(lp, [5, 4])          # overflow still rejected


# -- packed path: bit-identity against AoS --------------------------------

def _satellite_batch(kind: str):
    if kind == "adversarial":
        return adversarial_lp(6, 24)
    if kind == "infeasible":
        return infeasible_lp(5, 12)
    return ragged_feasible_lp(jax.random.key(13), 9, 21, m_min=2)


@pytest.mark.parametrize("backend", ["naive", "rgb", "kernel"])
@pytest.mark.parametrize("kind", ["adversarial", "infeasible", "ragged"])
def test_packed_path_bit_identical(kind, backend):
    """Solving a pre-packed batch must be *bit-identical* to solving the
    AoS batch it came from, on every backend — the layout is a
    representation change, not a numerical one."""
    lp = _satellite_batch(kind)
    spec = SolverSpec(backend=backend,
                      interpret=True if backend == "kernel" else None)
    solver = get_solver(spec)
    aos = solver.solve(lp)
    soa = solver.solve(pack(lp))
    np.testing.assert_array_equal(np.asarray(aos.x), np.asarray(soa.x))
    np.testing.assert_array_equal(np.asarray(aos.feasible),
                                  np.asarray(soa.feasible))
    np.testing.assert_array_equal(np.asarray(aos.objective),
                                  np.asarray(soa.objective))


def test_packed_path_bit_identical_with_shuffle():
    """The packed shuffle draws the same permutation as the AoS one
    (same key, same score shape), so bit-identity survives shuffling."""
    lp = _satellite_batch("ragged")
    spec = SolverSpec(backend="rgb", shuffle=True, seed=5)
    aos = get_solver(spec).solve(lp)
    soa = get_solver(spec).solve(pack(lp))
    np.testing.assert_array_equal(np.asarray(aos.x), np.asarray(soa.x))
    np.testing.assert_array_equal(np.asarray(aos.feasible),
                                  np.asarray(soa.feasible))


def test_padded_pack_shuffle_agrees_to_tolerance():
    """Documented caveat: padding the constraint axis changes the shape
    the shuffle scores are drawn from, so a bucket-padded pack is *not*
    bit-identical under shuffle=True — but the optimum is
    order-invariant, so objectives still agree to the paper's
    tolerance (and without shuffle, padding preserves bit-identity)."""
    lp = _satellite_batch("ragged")
    shuf = SolverSpec(backend="rgb", shuffle=True, seed=5)
    a = get_solver(shuf).solve(lp)
    p = get_solver(shuf).solve(pack(lp, m_pad=128))
    np.testing.assert_array_equal(np.asarray(a.feasible),
                                  np.asarray(p.feasible))
    np.testing.assert_allclose(np.asarray(a.objective),
                               np.asarray(p.objective),
                               rtol=TOL_5SIG, atol=TOL_5SIG)
    plain = SolverSpec(backend="rgb")
    np.testing.assert_array_equal(
        np.asarray(get_solver(plain).solve(lp).x),
        np.asarray(get_solver(plain).solve(pack(lp, m_pad=128)).x))


# -- cross-backend equivalence property -----------------------------------

_GENERATORS = ("random", "ragged", "infeasible")


def _gen_batch(kind: str, seed: int, batch: int, m: int):
    if kind == "random":
        return random_feasible_lp(jax.random.key(seed), batch, m)
    if kind == "ragged":
        return ragged_feasible_lp(jax.random.key(seed), batch, max(m, 5),
                                  m_min=2)
    return infeasible_lp(batch, m)


@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(_GENERATORS), seed=st.integers(0, 2**30),
       batch=st.integers(1, 12), m=st.integers(3, 40))
def test_backends_agree_property(kind, seed, batch, m):
    """naive, rgb (dense and chunked) and kernel(interpret) agree on
    feasibility and on the objective to the paper's 5-significant-figure
    tolerance, across random/ragged/infeasible generators."""
    lp = _gen_batch(kind, seed, batch, m)
    # pack/unpack round-trip law: the packed layout is lossless
    rt = unpack(pack(lp))
    for f in ("A", "b", "c", "m_valid"):
        np.testing.assert_array_equal(np.asarray(getattr(rt, f)),
                                      np.asarray(getattr(lp, f)))
    sweep = (
        SolverSpec(backend="naive", shuffle=True, seed=seed),
        SolverSpec(backend="rgb", shuffle=True, seed=seed),
        SolverSpec(backend="rgb", tile=8, chunk=64, shuffle=True,
                   seed=seed),
        SolverSpec(backend="kernel", interpret=True, shuffle=True,
                   seed=seed),
    )
    sols = [get_solver(s).solve(lp) for s in sweep]
    ref = sols[0]
    for spec, sol in zip(sweep[1:], sols[1:]):
        np.testing.assert_array_equal(
            np.asarray(ref.feasible), np.asarray(sol.feasible),
            err_msg=f"feasibility mismatch: {spec}")
        feas = np.asarray(ref.feasible)
        if feas.any():
            np.testing.assert_allclose(
                np.asarray(ref.objective)[feas],
                np.asarray(sol.objective)[feas],
                rtol=TOL_5SIG, atol=TOL_5SIG,
                err_msg=f"objective mismatch: {spec}")
    # the first-order backend classifies feasibility identically and
    # matches the exact optimum to its KKT stopping tolerance (looser
    # than the vertex-exact Seidel agreement above)
    pdhg = get_solver(SolverSpec(backend="pdhg", tol=1e-5)).solve(lp)
    np.testing.assert_array_equal(np.asarray(ref.feasible),
                                  np.asarray(pdhg.feasible),
                                  err_msg="feasibility mismatch: pdhg")
    feas = np.asarray(ref.feasible)
    if feas.any():
        np.testing.assert_allclose(np.asarray(ref.objective)[feas],
                                   np.asarray(pdhg.objective)[feas],
                                   rtol=2e-3, atol=2e-3,
                                   err_msg="objective mismatch: pdhg")
