"""RPC front-end tests.

Admission control is exercised *without sockets* by driving
``LPFrontend.handle`` directly with synthetic :class:`Request` objects
(validation, quota exhaustion, deadline expiry, 429 backpressure, SLO
planning), plus one real-socket round-trip smoke over
``RpcServer``/``run_in_thread``.  Correctness criterion: an accepted
request's answer is bit-identical to a direct ``BatchScheduler.submit``
of the same LP.
"""
import asyncio
import json
import math
import time
from concurrent.futures import InvalidStateError

import jax
import numpy as np
import pytest

from repro.serve_lp import BatchScheduler, ExecutableCache, SolverSpec
from repro.serve_lp.metrics import ServeMetrics
from repro.serve_lp.rpc import (AdmissionPolicy, QuotaManager, Request,
                                RpcError, SLOController, TokenBucket,
                                check_backpressure, make_frontend,
                                parse_solve_payload, render_metrics,
                                run_in_thread, validate_exposition)
from repro.tune.table import TableEntry, TableKey, TuningTable

SPEC = SolverSpec(backend="rgb", tile=16, chunk=0)


def _lp(seed=0, m=3):
    rng = np.random.default_rng(seed)
    xstar = rng.uniform(-10, 10, 2)
    theta = rng.uniform(0, 2 * np.pi, m)
    A = np.stack([np.cos(theta), np.sin(theta)], -1).astype(np.float32)
    b = (A @ xstar + rng.uniform(0.1, 3.0, m)).astype(np.float32)
    phi = rng.uniform(0, 2 * np.pi)
    c = np.array([np.cos(phi), np.sin(phi)], np.float32)
    return A, b, c


def _problem_json(A, b, c, **extra):
    return {"A": A.tolist(), "b": b.tolist(), "c": c.tolist(), **extra}


def _post(frontend, obj, headers=None):
    req = Request("POST", "/v1/solve",
                  {k.lower(): v for k, v in (headers or {}).items()},
                  json.dumps(obj).encode())
    return asyncio.run(frontend.handle(req))


def _get(frontend, path):
    return asyncio.run(frontend.handle(Request("GET", path, {})))


def _body(resp):
    return json.loads(resp.body)


@pytest.fixture
def frontend():
    f = make_frontend(SPEC, max_batch=4, max_wait_s=0.003)
    f.start()
    yield f
    f.close()


@pytest.fixture
def frontend_slo():
    f = make_frontend(SPEC, max_batch=4, max_wait_s=0.003,
                      target_p99_s=0.05)
    f.start()
    yield f
    f.close()


# -- token buckets --------------------------------------------------------

def test_token_bucket_refill_and_pricing():
    t = [0.0]
    bucket = TokenBucket(rate=10.0, burst=5.0, clock=lambda: t[0])
    assert bucket.try_take(5.0) == 0.0          # burst admitted
    retry = bucket.try_take(1.0)                # empty: priced rejection
    assert retry == pytest.approx(0.1)
    t[0] += 0.1                                 # refill exactly 1 token
    assert bucket.try_take(1.0) == 0.0
    assert bucket.try_take(math.inf if False else 6.0) == math.inf
    t[0] += 100.0                               # cap at burst
    assert bucket.tokens == pytest.approx(5.0)


def test_quota_manager_per_tenant_and_counters():
    t = [0.0]
    q = QuotaManager(rate=100.0, burst=10.0,
                     per_tenant={"vip": (1000.0, 100.0)},
                     clock=lambda: t[0])
    assert q.admit("vip", 50.0) == 0.0          # override honoured
    assert q.admit("anon", 50.0) == math.inf    # over default burst
    assert q.admit("anon", 10.0) == 0.0
    assert q.admit("anon", 1.0) > 0.0
    snap = q.snapshot()
    assert snap["anon"]["admitted"] == 10
    assert snap["anon"]["rejected"] == 51
    assert snap["vip"]["admitted"] == 50


# -- validation (socket-free, parse layer) --------------------------------

@pytest.mark.parametrize("body,status,code", [
    (b"{not json", 400, "bad_json"),
    (b'[1,2]', 400, "bad_request"),
    (json.dumps({"A": [[1, 0]], "b": [1]}).encode(), 422,
     "missing_field"),
    (json.dumps({"A": [[1, 0, 2]], "b": [1], "c": [1, 1]}).encode(),
     422, "bad_shape"),
    (json.dumps({"A": [], "b": [], "c": [1, 1]}).encode(), 422,
     "bad_shape"),
    (json.dumps({"A": [[1, 0]], "b": [1, 2], "c": [1, 1]}).encode(),
     422, "bad_shape"),
    (json.dumps({"A": [[1, 0]], "b": [1], "c": [1, 1, 1]}).encode(),
     422, "bad_shape"),
    (json.dumps({"A": [[1, "x"]], "b": [1], "c": [1, 1]}).encode(),
     422, "bad_dtype"),
    (json.dumps({"A": [[1, float("nan")]], "b": [1],
                 "c": [1, 1]}).encode(), 422, "nonfinite"),
    (json.dumps({"problems": []}).encode(), 422, "bad_request"),
])
def test_parse_rejections_typed(body, status, code):
    with pytest.raises(RpcError) as ei:
        parse_solve_payload(body, np.float32, AdmissionPolicy())
    assert ei.value.status == status
    assert ei.value.code == code


def test_parse_bounds():
    A, b, c = _lp(m=9)
    policy = AdmissionPolicy(m_max=8, batch_max=2)
    with pytest.raises(RpcError) as ei:
        parse_solve_payload(
            json.dumps(_problem_json(A, b, c)).encode(), np.float32,
            policy)
    assert (ei.value.status, ei.value.code) == (422, "m_out_of_bounds")
    A, b, c = _lp(m=3)
    probs = {"problems": [_problem_json(A, b, c)] * 3}
    with pytest.raises(RpcError) as ei:
        parse_solve_payload(json.dumps(probs).encode(), np.float32,
                            policy)
    assert (ei.value.status, ei.value.code) == (413, "batch_too_large")
    with pytest.raises(RpcError) as ei:
        parse_solve_payload(b"x" * 100, np.float32,
                            AdmissionPolicy(body_max_bytes=10))
    assert (ei.value.status, ei.value.code) == (413, "body_too_large")


def test_validation_never_touches_scheduler(frontend):
    resp = _post(frontend, {"A": [[1, 0, 3]], "b": [1], "c": [1, 1]})
    assert resp.status == 422
    assert frontend.scheduler.pending() == 0
    assert frontend.scheduler.metrics.n_solved == 0
    assert frontend.counters.snapshot()["lps_accepted"] == 0


# -- solving through the handler ------------------------------------------

def test_single_and_batch_solve_bit_identical_to_direct(frontend):
    lps = [_lp(seed=s, m=m) for s, m in
           [(1, 3), (2, 5), (3, 8), (4, 3)]]
    # through the RPC handler (batch form)
    resp = _post(frontend, {"problems":
                            [_problem_json(*lp) for lp in lps]})
    assert resp.status == 200
    results = _body(resp)["results"]
    assert len(results) == len(lps)
    # direct submit of the same arrays with the same spec
    with BatchScheduler(SPEC, max_batch=len(lps)) as direct:
        futs = [direct.submit(*lp) for lp in lps]
        direct.flush()
        want = [f.result(timeout=60) for f in futs]
    for got, ref in zip(results, want):
        assert got["feasible"] == bool(ref.feasible)
        np.testing.assert_array_equal(
            np.asarray(got["x"], np.float32), ref.x)
    # single form mirrors batch form
    resp = _post(frontend, _problem_json(*lps[0]))
    assert resp.status == 200
    np.testing.assert_array_equal(
        np.asarray(_body(resp)["result"]["x"], np.float32), want[0].x)


def test_internal_errors_do_not_leak_reprs(frontend, monkeypatch):
    def _boom(*a, **k):
        raise RuntimeError("secret-internal-detail /opt/private/path")

    monkeypatch.setattr(frontend.quotas, "admit", _boom)
    resp = _post(frontend, _problem_json(*_lp()))
    assert resp.status == 500
    assert _body(resp)["error"]["code"] == "internal"
    assert "secret-internal-detail" not in resp.body.decode()
    # the repr lands in the server-side error counter instead
    assert frontend.scheduler.metrics.errors.get("rpc_internal") == 1


def test_oversized_lines_get_400_not_connection_drop():
    # StreamReader.readline signals over-limit lines as ValueError;
    # both the request line and header lines must map it to a 400.
    from repro.serve_lp.rpc.server import _read_request

    def _parse(payload):
        async def _run():
            reader = asyncio.StreamReader(limit=1024)
            reader.feed_data(payload)
            reader.feed_eof()
            return await _read_request(reader, body_max=1 << 20)
        return asyncio.run(_run())

    with pytest.raises(RpcError) as ei:
        _parse(b"GET /" + b"x" * 4096 + b" HTTP/1.1\r\n\r\n")
    assert (ei.value.status, ei.value.code) == (400, "bad_request")
    with pytest.raises(RpcError) as ei:
        _parse(b"POST /v1/solve HTTP/1.1\r\nx-big: " + b"y" * 4096
               + b"\r\n\r\n")
    assert (ei.value.status, ei.value.code) == (400, "bad_request")


def test_method_and_route_errors(frontend):
    resp = asyncio.run(frontend.handle(
        Request("GET", "/v1/solve", {})))
    assert resp.status == 405
    resp = asyncio.run(frontend.handle(Request("GET", "/nope", {})))
    assert resp.status == 404
    snap = frontend.counters.snapshot()
    assert snap["requests"][("solve", 405)] == 1
    assert snap["requests"][("other", 404)] == 1


# -- quotas through the handler -------------------------------------------

def test_quota_exhaustion_429_then_refill():
    t = [0.0]
    f = make_frontend(
        SPEC, max_batch=1, max_wait_s=0.003,
        quotas=QuotaManager(rate=100.0, burst=2.0, clock=lambda: t[0]))
    f.start()
    try:
        prob = _problem_json(*_lp())
        assert _post(f, prob, {"X-Tenant": "t1"}).status == 200
        assert _post(f, prob, {"X-Tenant": "t1"}).status == 200
        resp = _post(f, prob, {"X-Tenant": "t1"})
        assert resp.status == 429
        err = _body(resp)["error"]
        assert err["code"] == "quota_exhausted"
        assert resp.headers["Retry-After"] == "1"
        assert err["retry_after_ms"] == pytest.approx(10.0, abs=1.0)
        # an unrelated tenant is unaffected
        assert _post(f, prob, {"X-Tenant": "t2"}).status == 200
        # refill admits t1 again
        t[0] += 0.05
        assert _post(f, prob, {"X-Tenant": "t1"}).status == 200
        assert f.counters.snapshot()["shed"]["quota_exhausted"] == 1
    finally:
        f.close()


def test_batch_over_burst_is_413_not_retryable():
    f = make_frontend(SPEC, max_batch=8, max_wait_s=0.003,
                      quotas=QuotaManager(rate=100.0, burst=2.0))
    f.start()
    try:
        probs = {"problems": [_problem_json(*_lp())] * 3}
        resp = _post(f, probs)
        assert resp.status == 413
        assert _body(resp)["error"]["code"] == "batch_exceeds_burst"
        assert "Retry-After" not in resp.headers
    finally:
        f.close()


# -- deadlines -------------------------------------------------------------

def test_bad_deadline_rejected(frontend):
    resp = _post(frontend, _problem_json(*_lp()),
                 {"X-Deadline-Ms": "bogus"})
    assert resp.status == 400
    assert _body(resp)["error"]["code"] == "bad_deadline"
    resp = _post(frontend, _problem_json(*_lp()),
                 {"X-Deadline-Ms": "-5"})
    assert resp.status == 400


def test_deadline_expiry_cancels_instead_of_solving():
    # A scheduler that will never flush on its own: the request sits
    # queued until the deadline fires, the handler answers 504, and the
    # cancelled work is dropped at the next flush instead of solved.
    f = make_frontend(SPEC, max_batch=4096, max_wait_s=30.0)
    f.start()
    try:
        t0 = time.perf_counter()
        resp = _post(f, _problem_json(*_lp()),
                     {"X-Deadline-Ms": "40"})
        waited = time.perf_counter() - t0
        assert resp.status == 504
        assert _body(resp)["error"]["code"] == "deadline_exceeded"
        assert waited < 5.0          # did not wait for the 30s timer
        assert f.counters.snapshot()["shed"]["deadline_exceeded"] == 1
        sched = f.scheduler
        assert sched.pending() == 1  # still queued, future cancelled
        sched.flush()                # drops the cancelled request
        sched.drain()
        assert sched.metrics.n_solved == 0
        assert sched.metrics.n_flushes == 0
    finally:
        f.close()


def test_deadline_header_wins_over_body(frontend):
    # generous header, absurd body field: header must win -> solves
    resp = _post(frontend, _problem_json(*_lp(), deadline_ms=0.001),
                 {"X-Deadline-Ms": "60000"})
    assert resp.status == 200


# -- backpressure ----------------------------------------------------------

class _StubSched:
    def __init__(self, pending=0, inflight=0, max_inflight=2, age=0.0):
        self._pending, self._age = pending, age
        self.inflight, self.max_inflight = inflight, max_inflight

    def pending(self):
        return self._pending

    def queue_age_s(self, now=None):
        return self._age


def test_backpressure_depth_and_age_signals():
    policy = AdmissionPolicy(max_pending=10, max_queue_age_s=0.2)
    # healthy: deep queue but device not saturated
    check_backpressure(_StubSched(pending=50, inflight=1), policy)
    # depth: queue deep AND in-flight at bound
    with pytest.raises(RpcError) as ei:
        check_backpressure(_StubSched(pending=10, inflight=2), policy)
    assert ei.value.status == 429
    assert ei.value.retry_after_s is not None
    # age: oldest request waited too long
    with pytest.raises(RpcError):
        check_backpressure(_StubSched(age=0.5), policy)


def test_backpressure_sheds_through_handler():
    f = make_frontend(SPEC, max_batch=4096, max_wait_s=30.0,
                      policy=AdmissionPolicy(max_queue_age_s=0.0))
    f.start()
    try:
        # age an (unflushable) queued request past the 0.0s bound
        f.scheduler.submit(*_lp())
        time.sleep(0.01)
        resp = _post(f, _problem_json(*_lp()))
        assert resp.status == 429
        assert _body(resp)["error"]["code"] == "overloaded"
        assert "Retry-After" in resp.headers
        assert f.counters.snapshot()["shed"]["overloaded"] == 1
        assert f.scheduler.pending() == 1   # shed was never queued
    finally:
        f.close()


def test_shed_request_costs_no_quota_tokens():
    # Backpressure runs before quota: a 429-shed request must not also
    # deduct the tenant's token-bucket balance.
    quotas = QuotaManager(rate=100.0, burst=10.0)
    f = make_frontend(SPEC, max_batch=4096, max_wait_s=30.0,
                      policy=AdmissionPolicy(max_queue_age_s=0.0),
                      quotas=quotas)
    f.start()
    try:
        f.scheduler.submit(*_lp())
        time.sleep(0.01)
        resp = _post(f, _problem_json(*_lp()), {"X-Tenant": "t1"})
        assert resp.status == 429
        snap = quotas.snapshot()
        assert "t1" not in snap or (snap["t1"]["admitted"] == 0
                                    and snap["t1"]["rejected"] == 0)
    finally:
        f.close()


# -- SLO controller --------------------------------------------------------

def _measured_table(us_per_lp, m_bucket=8, tile=16):
    return TuningTable([TableEntry(
        key=TableKey(device_kind="cpu", backend="rgb",
                     dtype="float32", m_bucket=m_bucket,
                     batch_bucket=0),
        tile=tile, chunk=0, us_per_lp=us_per_lp, source="measured")])


def test_slo_derives_limits_from_measured_latency():
    # one pinned device so the flush-time arithmetic below stays exact
    # whatever XLA_FLAGS forced-device count the suite runs under
    sched = BatchScheduler(SPEC, max_batch=256, max_wait_s=0.005,
                           devices=jax.devices()[:1])
    slo = SLOController(0.05, table=_measured_table(50.0),
                        device_kind="cpu")
    slo.install(sched, m_max=8)
    plan = slo.plans()[8]
    assert plan.source == "measured"
    # est_flush = 50us * 256 = 12.8ms; wait = 50 - 2*12.8 = 24.4ms —
    # the acceptance contract: derived max_wait_s differs from the
    # 5ms default when a measured table is active.
    assert plan.est_flush_s == pytest.approx(12.8e-3)
    assert plan.max_wait_s == pytest.approx(24.4e-3)
    assert plan.max_wait_s != 0.005
    assert plan.max_batch == 256
    # and the scheduler consults the installed plan per bucket
    assert sched._limits_for(8) == (plan.max_batch, plan.max_wait_s)


def test_slo_caps_batch_for_slow_buckets():
    sched = BatchScheduler(SPEC, max_batch=256, max_wait_s=0.005,
                           devices=jax.devices()[:1])
    slo = SLOController(0.05, table=_measured_table(500.0),
                        device_kind="cpu")
    slo.install(sched, m_max=8)
    plan = slo.plans()[8]
    # 500us/LP: a 256-batch flush is 128ms >> the 25ms service budget;
    # halving lands on 32 (16ms).
    assert plan.max_batch == 32
    assert plan.est_flush_s == pytest.approx(16e-3)
    assert plan.max_wait_s == pytest.approx(0.05 - 32e-3)


def test_slo_defaults_without_measurements():
    sched = BatchScheduler(SPEC, max_batch=64, max_wait_s=0.004)
    slo = SLOController(0.05, table=TuningTable(), device_kind="cpu")
    slo.install(sched, m_max=16)
    for plan in slo.plans().values():
        assert plan.source == "default"
        assert plan.max_batch == 64
        assert plan.max_wait_s == 0.004
    assert sched._limits_for(8) == (64, 0.004)


def test_slo_ignores_heuristic_seeded_entries():
    table = TuningTable([TableEntry(
        key=TableKey(device_kind="cpu", backend="rgb",
                     dtype="float32", m_bucket=8, batch_bucket=0),
        tile=16, chunk=0, us_per_lp=1e9, source="heuristic-seed")])
    sched = BatchScheduler(SPEC, max_batch=64, max_wait_s=0.004)
    slo = SLOController(0.05, table=table, device_kind="cpu")
    slo.install(sched, m_max=8)
    assert slo.plans()[8].source == "default"


def test_slo_allow_fuse_veto_from_next_rung_timing():
    """Fusing solves a bucket at the next ladder rung's m_pad; when the
    measured timing there blows the flush-service budget, the plan
    vetoes fusing and the installed policy keeps the bucket out of
    fused units — while slower-but-unmeasured rungs stay fusable."""
    table = TuningTable([
        TableEntry(key=TableKey(device_kind="cpu", backend="rgb",
                                dtype="float32", m_bucket=8,
                                batch_bucket=0),
                   tile=16, chunk=0, us_per_lp=50.0, source="measured"),
        # the m=16 rung is measured catastrophically slow: a fused
        # flush carrying bucket-8 work at m_pad=16 would blow the p99
        TableEntry(key=TableKey(device_kind="cpu", backend="rgb",
                                dtype="float32", m_bucket=16,
                                batch_bucket=0),
                   tile=16, chunk=0, us_per_lp=1e5, source="measured"),
    ])
    sched = BatchScheduler(SPEC, max_batch=256, max_wait_s=0.005,
                           devices=jax.devices()[:1])
    slo = SLOController(0.05, table=table, device_kind="cpu")
    slo.install(sched, m_max=16)
    plans = slo.plans()
    assert plans[8].allow_fuse is False
    # bucket 16's own next rung (32) has no measurement: fusable
    assert plans[16].allow_fuse is True
    assert sched._fuse_ok(8) is False
    assert sched._fuse_ok(16) is True


def test_slo_flush_estimate_divides_by_used_devices_only():
    """The mesh-aware service model: a full flush spreads over all
    devices, so its estimated service time shrinks with the device
    count — which loosens the batch cap relative to one device."""
    sched = BatchScheduler(SPEC, max_batch=256, max_wait_s=0.005,
                           devices=jax.devices()[:1])
    # planning-only stand-in for a 4-device mesh (no executables are
    # built through this scheduler)
    sched._devices = sched._devices * 4
    slo = SLOController(0.05, table=_measured_table(50.0),
                        device_kind="cpu")
    plan = slo.plan_for(sched, 8)
    # 50us/LP * 256 rows over min(4, 256/16)=4 used devices = 3.2ms
    assert plan.est_flush_s == pytest.approx(3.2e-3)
    assert plan.max_batch == 256


def test_render_metrics_slo_and_sharding_families():
    """The scrape exposes the SLO per-bucket plans and the fused/launch
    counters as labelled families."""
    from repro.serve_lp.rpc.slo import BucketPlan
    m = ServeMetrics()
    m.record_flush(n_real=3, b_pad=16, bucket_m=16, sum_m=30,
                   solve_seconds=0.01, reason="fused", n_buckets=2,
                   launches=2, shards=(8, 8))
    snap = m.snapshot()
    plans = {8: BucketPlan(bucket_m=8, max_batch=32, max_wait_s=0.01,
                           est_flush_s=0.004, source="measured",
                           allow_fuse=False),
             16: BucketPlan(bucket_m=16, max_batch=64, max_wait_s=0.02,
                            est_flush_s=None, source="default")}
    text = render_metrics(snap, slo=plans)
    validate_exposition(text)
    assert ('repro_serve_slo_bucket_max_batch{bucket_m="8",'
            'source="measured"} 32') in text
    assert ('repro_serve_slo_bucket_max_wait_seconds{bucket_m="16",'
            'source="default"} 0.02') in text
    assert ('repro_serve_slo_bucket_allow_fuse{bucket_m="8",'
            'source="measured"} 0') in text
    assert ('repro_serve_slo_bucket_allow_fuse{bucket_m="16",'
            'source="default"} 1') in text
    # est_flush renders 0 (not NaN) when no measured entry exists
    assert ('repro_serve_slo_bucket_est_flush_seconds{bucket_m="16",'
            'source="default"} 0') in text
    assert "repro_serve_launches_total 2" in text
    assert "repro_serve_fused_flushes_total 1" in text
    assert "repro_serve_fused_buckets_total 2" in text
    assert 'repro_serve_device_rows_total{device="0"} 8' in text
    assert 'repro_serve_device_rows_total{device="1"} 8' in text


def test_metrics_endpoint_exposes_slo_plans(frontend_slo):
    """An SLO-enabled front end publishes its per-bucket plans on
    /metrics after traffic has touched a bucket."""
    _post(frontend_slo, _problem_json(*_lp()))
    resp = _get(frontend_slo, "/metrics")
    assert resp.status == 200
    text = resp.body.decode()
    validate_exposition(text)
    assert "repro_serve_slo_bucket_max_batch{" in text
    assert "repro_serve_slo_bucket_allow_fuse{" in text


def test_scheduler_per_bucket_policy_drives_size_trigger():
    with BatchScheduler(SPEC, max_batch=64, max_wait_s=10.0) as sched:
        sched.set_bucket_policy(lambda bm: (2, 10.0))
        f1 = sched.submit(*_lp(seed=1))
        f2 = sched.submit(*_lp(seed=2))   # second hits the per-bucket cap
        r1, r2 = f1.result(timeout=60), f2.result(timeout=60)
        assert r1.batch_size == 2 and r2.batch_size == 2
        assert sched.metrics.flush_reasons.get("size") == 1


# -- prometheus exposition -------------------------------------------------

def test_fresh_metrics_render_nan_free():
    # empty-reservoir guard: a scrape before any traffic must be finite
    m = ServeMetrics()
    assert m.percentile(99.0) == 0.0
    snap = m.snapshot({"hits": 0, "misses": 0, "size": 0,
                       "hit_rate": 0.0})
    text = render_metrics(snap, rpc={"requests": {}, "shed": {},
                                     "inprogress": 0,
                                     "lps_accepted": 0},
                          quotas={})
    validate_exposition(text)
    samples = [ln for ln in text.splitlines()
               if ln and not ln.startswith("#")]
    assert samples and all(
        math.isfinite(float(ln.rsplit(" ", 1)[1])) for ln in samples)


def test_metrics_endpoint_exposes_scheduler_and_rpc_counters(frontend):
    resp = _get(frontend, "/metrics")         # pre-traffic scrape
    assert resp.status == 200
    validate_exposition(resp.body.decode())
    _post(frontend, _problem_json(*_lp()))
    _post(frontend, {"A": "garbage", "b": [1], "c": [1, 1]})
    resp = _get(frontend, "/metrics")
    text = resp.body.decode()
    validate_exposition(text)
    assert resp.content_type.startswith("text/plain; version=0.0.4")
    assert "repro_serve_solved_total 1" in text
    assert ('repro_serve_rpc_requests_total{code="200",'
            'endpoint="solve"} 1') in text
    assert ('repro_serve_rpc_requests_total{code="422",'
            'endpoint="solve"} 1') in text
    assert 'repro_serve_rpc_quota_admitted_total{tenant="anonymous"} 1' \
        in text


def test_health_and_ready(frontend):
    assert _get(frontend, "/healthz").status == 200
    assert _get(frontend, "/readyz").status == 200
    frontend.close()
    assert _get(frontend, "/healthz").status == 200   # alive, draining
    assert _get(frontend, "/readyz").status == 503


# -- drain() satellite -----------------------------------------------------

class _SlowExec:
    def __init__(self, delay):
        self.delay = delay

    def dispatch(self, L, c, mv):
        return (np.zeros((L.shape[0], 2), np.float32),
                np.zeros((L.shape[0],), bool))

    def complete(self, handle):
        time.sleep(self.delay)
        return handle


def test_drain_returns_false_on_timeout_then_true():
    sched = BatchScheduler(SPEC, max_batch=2, max_wait_s=10.0)
    sched.cache = ExecutableCache(lambda spec: _SlowExec(0.4))
    futs = [sched.submit(*_lp(seed=s)) for s in (1, 2)]  # size flush
    assert sched.drain(timeout=0.05) is False   # still completing
    assert sched.drain(timeout=30.0) is True
    for f in futs:
        assert f.result(timeout=1).feasible is False
    sched.close()


def test_stop_records_drain_timeout(monkeypatch):
    sched = BatchScheduler(SPEC, max_batch=8, max_wait_s=10.0)
    monkeypatch.setattr(sched, "drain", lambda timeout=600.0: False)
    with pytest.warns(RuntimeWarning, match="timed out draining"):
        sched.stop()
    assert sched.metrics.errors.get("drain_timeout") == 1


def test_cancelled_future_skipped_at_scatter():
    with BatchScheduler(SPEC, max_batch=64, max_wait_s=10.0) as sched:
        f1 = sched.submit(*_lp(seed=1))
        f2 = sched.submit(*_lp(seed=2))
        assert f1.cancel()
        sched.flush()
        sched.drain()
        assert f2.result(timeout=60).feasible
        assert f1.cancelled()
        assert not sched.metrics.errors


def test_flush_claims_futures_so_cancel_cannot_race_completion():
    # Once a flush picks a request up, the deadline machinery's
    # cancel() must lose cleanly (return False) instead of racing the
    # completion scatter into InvalidStateError.
    sched = BatchScheduler(SPEC, max_batch=2, max_wait_s=10.0)
    sched.cache = ExecutableCache(lambda spec: _SlowExec(0.3))
    try:
        f1 = sched.submit(*_lp(seed=1))
        f2 = sched.submit(*_lp(seed=2))   # size flush: both claimed
        assert f1.cancel() is False       # too late — the flush owns it
        assert f1.result(timeout=30) is not None
        assert f2.result(timeout=30) is not None
        assert not sched.metrics.errors
    finally:
        sched.close()


class _RacedFuture:
    """done() still says pending, but a cross-thread cancel already
    won — the window the done() pre-check cannot close."""

    def done(self):
        return False

    def set_result(self, value):
        raise InvalidStateError("cancelled")

    def set_exception(self, exc):
        raise InvalidStateError("cancelled")


def test_settle_tolerates_lost_cancel_race():
    from repro.serve_lp.scheduler import (_try_set_exception,
                                          _try_set_result)
    assert _try_set_result(_RacedFuture(), 1) is False
    assert _try_set_exception(_RacedFuture(), ValueError("x")) is False


# -- real-socket smoke -----------------------------------------------------

def test_socket_roundtrip_smoke():
    import http.client
    f = make_frontend(SPEC, max_batch=4, max_wait_s=0.003)
    port, stop = run_in_thread(f)
    try:
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        A, b, c = _lp()
        body = json.dumps(_problem_json(A, b, c))
        # keep-alive: several requests over one connection
        conn.request("POST", "/v1/solve", body,
                     {"X-Tenant": "sock", "X-Deadline-Ms": "60000"})
        resp = conn.getresponse()
        assert resp.status == 200
        got = json.loads(resp.read())["result"]
        with BatchScheduler(SPEC, max_batch=1) as direct:
            ref = direct.submit(A, b, c).result(timeout=60)
        np.testing.assert_array_equal(
            np.asarray(got["x"], np.float32), ref.x)
        conn.request("GET", "/metrics")
        resp = conn.getresponse()
        text = resp.read().decode()
        assert resp.status == 200
        validate_exposition(text)
        assert 'tenant="sock"' in text
        conn.request("GET", "/healthz")
        assert conn.getresponse().read() == b"ok\n"
        conn.request("POST", "/v1/solve", "{bad",
                     {"Content-Type": "application/json"})
        assert conn.getresponse().status == 400
        conn.close()
    finally:
        stop()
