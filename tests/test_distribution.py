"""Distribution correctness on real (forced-host) multi-device meshes.

Tests run in subprocesses so the main pytest process keeps exactly one
visible device (conftest.run_multidevice)."""
import textwrap

import jax
import pytest

# The state-space / pipeline model stack calls jax.lax.pvary, which this
# container's jax (0.4.37) predates.  Version-gate those tests (they are
# model-stack only, unrelated to the LP path) so tier-1 runs green and a
# real regression is visible; on a jax with pvary they run normally.
needs_pvary = pytest.mark.skipif(
    not hasattr(jax.lax, "pvary"),
    reason="jax.lax.pvary unavailable in this jax (needs >= 0.6); "
           "pre-existing model-stack limitation, see ROADMAP.md")

# Same story for jax.tree.leaves_with_path (elastic reshard test only).
needs_tree_paths = pytest.mark.skipif(
    not hasattr(jax.tree, "leaves_with_path"),
    reason="jax.tree.leaves_with_path unavailable in this jax; "
           "pre-existing model-stack limitation, see ROADMAP.md")

GRAD_SNIPPET = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    import warnings; warnings.filterwarnings("ignore")
    from repro.configs import ARCHS, smoke_config
    from repro.models import build_model, MeshInfo
    from repro.launch.mesh import mesh_info

    cfg = dataclasses.replace(smoke_config(ARCHS[{arch!r}]),
                              dtype="float32", **{extra})
    B, S = 4, 32
    batch = {{"tokens": jax.random.randint(jax.random.key(1), (B,S), 0,
                                           cfg.vocab, jnp.int32),
              "labels": jax.random.randint(jax.random.key(2), (B,S), 0,
                                           cfg.vocab, jnp.int32)}}
    m1 = build_model(dataclasses.replace(cfg, fsdp=False),
                     MeshInfo(model_size=1, data_size=1))
    params = m1.init(jax.random.key(0))
    g1 = jax.jit(jax.grad(lambda p: m1.loss(p, batch)[0]))(params)

    mesh = jax.make_mesh({mesh_shape}, ("data", "model"))
    m4 = build_model(cfg, mesh_info(mesh))
    def per_rank(p, b):
        loss, met = m4.loss(p, b)
        n = met["tokens"].astype(jnp.float32)
        return jax.lax.psum(loss*n, "data") / jax.lax.psum(n, "data")
    f = shard_map(per_rank, mesh=mesh,
                  in_specs=(m4.full_param_specs(),
                            {{k: P("data", None) for k in batch}}),
                  out_specs=P(), check_rep={check_rep})
    g4 = jax.jit(jax.grad(f))(params, batch)
    def cmp(t1, t2, path=""):
        if isinstance(t1, dict):
            for k in t1: cmp(t1[k], t2[k], path+"/"+k)
        else:
            a, b = np.asarray(t1, np.float32), np.asarray(t2, np.float32)
            err = np.max(np.abs(a-b)) / (np.max(np.abs(a)) + 1e-9)
            assert err < 2e-3, (path, float(err))
    cmp(g1, g4)
    print("OK")
""")


@pytest.mark.parametrize("arch,mesh_shape", [
    ("granite-8b", (1, 4)),
    ("granite-8b", (2, 2)),
    ("qwen2-0.5b", (2, 2)),
    pytest.param("mamba2-1.3b", (2, 2), marks=needs_pvary),
    pytest.param("zamba2-2.7b", (2, 2), marks=needs_pvary),
    ("whisper-base", (1, 4)),
    ("paligemma-3b", (2, 2)),
])
def test_tp_grads_match_single_device(multidevice, arch, mesh_shape):
    if arch in ("whisper-base", "paligemma-3b"):
        # these need modality inputs; token-only snippet covers them via
        # family defaults? no -> skip modality extras by using tokens-only
        # families here and modality archs in the smoke tests.
        pytest.skip("modality archs covered by single-device smoke tests")
    code = GRAD_SNIPPET.format(arch=arch, mesh_shape=mesh_shape,
                               check_rep=False, extra={})
    assert "OK" in multidevice(code, n_devices=4)


@needs_pvary
@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "arctic-480b"])
def test_moe_tp_exact_when_capacity_matches(multidevice, arch):
    """TP=4, DP=1 -> identical capacity to single device -> exact grads."""
    code = GRAD_SNIPPET.format(arch=arch, mesh_shape=(1, 4),
                               check_rep=False, extra={})
    assert "OK" in multidevice(code, n_devices=4)


def test_fsdp_grads_match(multidevice):
    code = GRAD_SNIPPET.format(
        arch="granite-8b", mesh_shape=(2, 2), check_rep=False,
        extra=dict(fsdp=True, fsdp_min_elems=1))
    assert "OK" in multidevice(code, n_devices=4)


def test_check_rep_true_grads_match(multidevice):
    code = GRAD_SNIPPET.format(arch="granite-8b", mesh_shape=(2, 2),
                               check_rep=True, extra={})
    assert "OK" in multidevice(code, n_devices=4)


def test_train_step_program_runs(multidevice):
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        import warnings; warnings.filterwarnings("ignore")
        from repro.configs import ARCHS, smoke_config
        from repro.launch.mesh import make_host_mesh
        from repro.launch import steps
        from repro.optim import AdamW
        cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
        mesh = make_host_mesh(2, 2)
        prog = steps.make_train_step(cfg, mesh, AdamW(lr=1e-3),
                                     global_batch=4)
        f = prog.jit()
        params = prog.model.init(jax.random.key(0))
        opt = AdamW(lr=1e-3).init(params)
        B, S = 4, 32
        batch = {"tokens": jnp.ones((B,S), jnp.int32),
                 "labels": jnp.ones((B,S), jnp.int32)}
        l0 = None
        extra = {}
        for i in range(5):
            params, opt, m, extra = f(params, opt, batch, extra)
            if l0 is None: l0 = float(m["loss"])
        assert float(m["loss"]) < l0, (l0, float(m["loss"]))
        print("OK")
    """)
    assert "OK" in multidevice(code, n_devices=4)


def test_manual_comm_matches_auto(multidevice):
    """manual-SPMD gradient path (psums written by hand) must produce the
    same training trajectory as autodiff-through-shard_map."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        import warnings; warnings.filterwarnings("ignore")
        from repro.configs import ARCHS, smoke_config
        from repro.launch.mesh import make_host_mesh
        from repro.launch import steps
        from repro.optim import AdamW
        cfg = dataclasses.replace(smoke_config(ARCHS["qwen1.5-0.5b"]),
                                  dtype="float32")
        mesh = make_host_mesh(2, 2)
        B, S = 4, 32
        batch = {"tokens": jax.random.randint(jax.random.key(1), (B,S), 0,
                                              cfg.vocab, jnp.int32),
                 "labels": jax.random.randint(jax.random.key(2), (B,S), 0,
                                              cfg.vocab, jnp.int32)}
        outs = {}
        for manual in (False, True):
            prog = steps.make_train_step(cfg, mesh, AdamW(lr=1e-3),
                                         global_batch=4,
                                         manual_comm=manual)
            f = prog.jit()
            params = prog.model.init(jax.random.key(0))
            opt = AdamW(lr=1e-3).init(params)
            extra = ({"err": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)}
                if manual else {})
            for i in range(3):
                params, opt, m, extra = f(params, opt, batch, extra)
            outs[manual] = float(m["loss"])
        assert abs(outs[False] - outs[True]) < 1e-3, outs
        print("OK")
    """)
    assert "OK" in multidevice(code, n_devices=4)


@needs_tree_paths
def test_elastic_reshard_checkpoint(multidevice, tmp_path):
    """Save on a (2,2) mesh, restore onto (4,1) and (1,4): the logical
    state must be identical (elastic rescaling)."""
    code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        import warnings; warnings.filterwarnings("ignore")
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import ARCHS, smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_host_mesh, mesh_info
        from repro.ckpt.checkpoint import Checkpointer
        cfg = smoke_config(ARCHS["granite-8b"])
        mesh_a = make_host_mesh(2, 2)
        m_a = build_model(cfg, mesh_info(mesh_a))
        params = m_a.init(jax.random.key(0))
        ck = Checkpointer({str(tmp_path)!r})
        ck.save(1, params, blocking=True)

        for shape in ((4, 1), (1, 4)):
            mesh_b = make_host_mesh(*shape)
            m_b = build_model(cfg, mesh_info(mesh_b))
            specs = m_b.full_param_specs()
            sh = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs,
                              is_leaf=lambda x: isinstance(x, P))
            like = jax.eval_shape(lambda: m_b.init(jax.random.key(0)))
            out, _ = ck.load(like, shardings=sh)
            for (ka, va), (kb, vb) in zip(
                    sorted(jax.tree.leaves_with_path(params),
                           key=lambda t: str(t[0])),
                    sorted(jax.tree.leaves_with_path(out),
                           key=lambda t: str(t[0]))):
                np.testing.assert_array_equal(
                    np.asarray(va, np.float32), np.asarray(vb, np.float32))
        print("OK")
    """)
    assert "OK" in multidevice(code, n_devices=4)


def test_lp_solver_sharded_over_mesh(multidevice):
    """The paper's workload on a mesh: batch-sharded LP solve must match
    the single-device solution."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        import warnings; warnings.filterwarnings("ignore")
        from repro.core import random_feasible_lp, \\
            normalize_batch, shuffle_batch
        from repro.solver import SolverSpec, get_solver
        from repro.launch.mesh import make_host_mesh
        from repro.launch import steps
        lp = shuffle_batch(jax.random.key(5), normalize_batch(
            random_feasible_lp(jax.random.key(0), 64, 24)))
        ref = get_solver(SolverSpec(backend="rgb", tile=32, chunk=0,
                                    normalize=False)).solve(lp)
        mesh = make_host_mesh(2, 2)
        prog = steps.make_lp_step(mesh, batch=64, m=24)
        out = prog.jit()({"A": lp.A, "b": lp.b, "c": lp.c,
                          "m_valid": lp.m_valid})
        np.testing.assert_allclose(np.asarray(ref.x), np.asarray(out["x"]),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in multidevice(code, n_devices=4)
