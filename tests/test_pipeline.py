"""Pipeline-parallel engine correctness (subprocess multi-device)."""
import textwrap

import jax
import pytest


@pytest.mark.skipif(
    not hasattr(jax.lax, "pvary"),
    reason="jax.lax.pvary unavailable in this jax (needs >= 0.6); "
           "pre-existing model-stack limitation, see ROADMAP.md")
def test_gpipe_matches_sequential(multidevice):
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, functools
        import warnings; warnings.filterwarnings("ignore")
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.pipeline import gpipe

        S, LPS, D, M, MB = 4, 2, 16, 8, 4  # stages, layers/stage, width
        L = S * LPS
        ks = jax.random.split(jax.random.key(0), 2)
        W = jax.random.normal(ks[0], (L, D, D)) * (1.0 / D ** 0.5)
        x = jax.random.normal(ks[1], (M, MB, D))

        def layer(w, h):
            return jax.nn.relu(h @ w)

        def stage_fn(ws, h):  # ws (LPS, D, D)
            def body(h, w):
                return layer(w, h), None
            h, _ = jax.lax.scan(body, h, ws)
            return h

        # sequential reference
        def seq(W, x):
            def body(h, w):
                return layer(w, h), None
            h, _ = jax.lax.scan(body, x.reshape(M * MB, D),
                                W)
            return h.reshape(M, MB, D)

        ref = seq(W, x)

        mesh = jax.make_mesh((S,), ("pipe",))
        Wr = W.reshape(S, LPS, D, D)

        def per_rank(Wl, xs):
            return gpipe(stage_fn, Wl[0], xs, n_stages=S)

        f = shard_map(per_rank, mesh=mesh,
                      in_specs=(P("pipe"), P()), out_specs=P())
        out = jax.jit(f)(Wr, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

        # gradients flow through the schedule (ppermute transpose)
        def loss_pp(Wr, x):
            return jnp.sum(f(Wr, x) ** 2)
        def loss_seq(W, x):
            return jnp.sum(seq(W, x) ** 2)
        g_pp = jax.jit(jax.grad(loss_pp))(Wr, x).reshape(L, D, D)
        g_seq = jax.jit(jax.grad(loss_seq))(W, x)
        np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_seq),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
    """)
    assert "OK" in multidevice(code, n_devices=4)


def test_bubble_fraction():
    from repro.launch.pipeline import bubble_fraction
    assert bubble_fraction(4, 8) == 3 / 11
    assert bubble_fraction(1, 8) == 0.0
    # more microbatches -> smaller bubble
    assert bubble_fraction(4, 64) < bubble_fraction(4, 8)
