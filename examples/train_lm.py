"""End-to-end LM training driver example (deliverable (b): train a model
for a few hundred steps).

On this CPU container it trains the reduced config; on a TPU pod drop
--smoke and add --production-mesh for the 16x16 layout.  Checkpoints are
mesh-independent: the same directory restores onto any mesh.

    PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch.train import main as train_main


def main():
    args = [
        "--arch", "qwen1.5-0.5b", "--smoke",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm",
        "--ckpt-every", "50", "--log-every", "20",
        "--heartbeat", "/tmp/repro_train_lm/heartbeat.json",
    ]
    loss = train_main(args + sys.argv[1:])
    assert loss < 5.0, f"training did not make progress: {loss}"
    print(f"trained to loss {loss:.4f}; checkpoint in /tmp/repro_train_lm "
          f"(re-run this script: it resumes from the checkpoint)")


if __name__ == "__main__":
    main()
