"""Quickstart: solve a batch of 2-D LPs three ways and compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core import (normalize_batch, random_feasible_lp, shuffle_batch,
                        solve_batch_lp)


def main():
    B, m = 4096, 128
    print(f"batch of {B} LPs with {m} constraints each")
    lp = random_feasible_lp(jax.random.key(0), B, m)
    # normalise once, pick a random consideration order (Seidel's R)
    lp = shuffle_batch(jax.random.key(1), normalize_batch(lp))

    sols = {}
    for method, kw in (
        ("naive", {}),                          # divergence baseline
        ("rgb", dict(tile=8, chunk=64)),        # cooperative tiles
        ("kernel", dict(interpret=True)),       # Pallas TPU kernel (CPU
    ):                                          # interpret mode here)
        f = jax.jit(lambda L, meth=method, kw=kw: solve_batch_lp(
            L, method=meth, normalize=False, **kw))
        out = f(lp)
        jax.block_until_ready(out.x)
        t0 = time.perf_counter()
        out = f(lp)
        jax.block_until_ready(out.x)
        dt = time.perf_counter() - t0
        sols[method] = out
        print(f"  {method:8s}: {dt*1e3:8.1f} ms "
              f"({dt/B*1e6:6.2f} us/LP), "
              f"{int(out.feasible.sum())}/{B} feasible")

    for k in ("rgb", "kernel"):
        np.testing.assert_allclose(np.asarray(sols["naive"].objective),
                                   np.asarray(sols[k].objective),
                                   rtol=5e-4, atol=5e-4)
    print("all methods agree to 5 significant figures "
          "(the paper's comparison tolerance)")


if __name__ == "__main__":
    main()
