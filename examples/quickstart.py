"""Quickstart: one batch of 2-D LPs, every backend, one spec sweep.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core import pack, random_feasible_lp
from repro.solver import SolverSpec


def main():
    B, m = 4096, 128
    print(f"batch of {B} LPs with {m} constraints each")
    lp = random_feasible_lp(jax.random.key(0), B, m)

    # One frozen spec per backend; shuffle=True applies Seidel's random
    # consideration order (keyed by seed) inside every solve.
    sweep = (
        SolverSpec(backend="naive", shuffle=True, seed=1),
        SolverSpec(backend="rgb", tile=8, chunk=64, shuffle=True, seed=1),
        SolverSpec(backend="kernel", interpret=True, shuffle=True,
                   seed=1),                      # Pallas kernel (CPU
    )                                            # interpret mode here)

    sols = {}
    for spec in sweep:
        solver = spec.build()
        out = solver.solve(lp)                   # compiles once per shape
        jax.block_until_ready(out.x)
        t0 = time.perf_counter()
        out = solver.solve(lp)                   # cache hit
        jax.block_until_ready(out.x)
        dt = time.perf_counter() - t0
        sols[spec.backend] = out
        print(f"  {spec.backend:8s}: {dt*1e3:8.1f} ms "
              f"({dt/B*1e6:6.2f} us/LP), "
              f"{int(out.feasible.sum())}/{B} feasible")

    for k in ("rgb", "kernel"):
        np.testing.assert_allclose(np.asarray(sols["naive"].objective),
                                   np.asarray(sols[k].objective),
                                   rtol=5e-4, atol=5e-4)
    print("all backends agree to 5 significant figures "
          "(the paper's comparison tolerance)")

    # Solving the same batch repeatedly?  Pack once into the canonical
    # SoA layout (the paper's "one extended set of data") and hand the
    # PackedLPBatch to any solver — results are bit-identical to the
    # AoS path, with zero per-call repacking.
    pb = pack(lp)
    solver = sweep[1].build()
    sol_packed = solver.solve(pb)
    np.testing.assert_array_equal(np.asarray(sol_packed.x),
                                  np.asarray(solver.solve(lp).x))
    print("pre-packed solve is bit-identical to the AoS solve")


if __name__ == "__main__":
    main()
