"""End-to-end training example with the paper's solver inside the
optimizer: every step solves a batch of per-parameter-block 2-D LPs that
pick a trust-region-safe update scale (optim/lp_clip.py).

    PYTHONPATH=src python examples/lp_constrained_training.py
"""
from repro.launch.train import main as train_main


def main():
    print("== baseline (plain AdamW) ==")
    loss_a = train_main([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "40",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--log-every", "10"])
    print("== LP-constrained updates (batch 2-D LP per block/step) ==")
    loss_b = train_main([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "40",
        "--batch", "8", "--seq", "64", "--lr", "3e-3", "--lp-clip",
        "--log-every", "10"])
    print(f"final losses: adamw={loss_a:.4f}  lp-clipped={loss_b:.4f}")
    print("(at an aggressive lr the LP trust region keeps early steps "
          "bounded; lp_s1 < 1 in the logs shows the constraint binding)")


if __name__ == "__main__":
    main()
