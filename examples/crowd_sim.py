"""Pedestrian collision avoidance with batch 2-D LPs — the paper's own
motivating application (section 5: "A practical use of the RGB algorithm
has been applied to an early model of pedestrian simulation").

Each agent solves one LP per time step: maximise progress along its
preferred direction subject to one half-plane constraint per neighbour
(an ORCA-style linear avoidance constraint) and the speed box.

By default each agent *submits its own LP* to the ``repro.serve_lp``
scheduler, which fuses them into one bucketed batch per step — the
serving path a real multi-tenant simulation (or millions of independent
clients) would use.  ``--direct`` keeps the original fully-fused,
fully-jitted single-batch path for comparison; both produce the same
trajectories.

    PYTHONPATH=src python examples/crowd_sim.py --agents 256 --steps 120
    PYTHONPATH=src python examples/crowd_sim.py --direct
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LPBatch
from repro.serve_lp import BatchScheduler
from repro.solver import SolverSpec

RADIUS = 0.3     # agent radius
V_MAX = 1.5      # speed box (the solver's M bound)
TAU = 2.0        # avoidance horizon
K_NEIGH = 8      # constraints per agent (nearest neighbours)

# One spec for both paths: the direct fused solve and the scheduler
# solve share it, so their trajectories match by construction.
SPEC = SolverSpec(backend="rgb", tile=8, chunk=64, M=V_MAX)
_SOLVER = SPEC.build()


def step_constraints(pos, vel_pref):
    """Build each agent's LP: A v <= b for its K nearest neighbours."""
    N = pos.shape[0]
    diff = pos[None, :, :] - pos[:, None, :]          # (N, N, 2)
    dist = jnp.linalg.norm(diff, axis=-1) + 1e-9
    dist = dist.at[jnp.arange(N), jnp.arange(N)].set(jnp.inf)
    _, idx = jax.lax.top_k(-dist, K_NEIGH)             # (N, K) nearest
    d_k = jnp.take_along_axis(dist, idx, axis=1)       # (N, K)
    n_k = jnp.take_along_axis(diff, idx[..., None], axis=1) / d_k[..., None]
    # closing-speed limit: v . n <= (gap)/tau  (gap = dist - 2r)
    gap = jnp.maximum(d_k - 2 * RADIUS, 1e-3)
    A = n_k                                            # (N, K, 2)
    b = gap / TAU
    c = vel_pref / (jnp.linalg.norm(vel_pref, axis=-1, keepdims=True)
                    + 1e-9)
    return LPBatch(A=A, b=b, c=c,
                   m_valid=jnp.full((N,), K_NEIGH, jnp.int32))


def apply_velocities(pos, x, feasible):
    """Position update from solved velocities (host-side numpy so it works
    with per-request scheduler results)."""
    # infeasible (overcrowded) agents stop for a step
    v = np.where(feasible[:, None], x, 0.0)
    speed = np.linalg.norm(v, axis=-1, keepdims=True)
    v = np.where(speed > V_MAX, v * V_MAX / np.maximum(speed, 1e-9), v)
    return pos + 0.1 * v


@jax.jit
def sim_step(pos, goal):
    vel_pref = goal - pos
    lp = step_constraints(pos, vel_pref)
    sol = _SOLVER(lp)  # composable __call__ inside the jitted step
    # infeasible (overcrowded) agents stop for a step
    v = jnp.where(sol.feasible[:, None], sol.x, 0.0)
    speed = jnp.linalg.norm(v, axis=-1, keepdims=True)
    v = jnp.where(speed > V_MAX, v * V_MAX / speed, v)
    return pos + 0.1 * v


_constraints_jit = jax.jit(step_constraints)


def sim_step_served(pos, goal, sched: BatchScheduler):
    """One step through the serving path: every agent submits its own LP;
    the scheduler fuses them, solves, and scatters results back."""
    lp = _constraints_jit(jnp.asarray(pos), jnp.asarray(goal - pos))
    futs = sched.submit_many(np.asarray(lp.A), np.asarray(lp.b),
                             np.asarray(lp.c))
    sched.flush()
    res = [f.result(timeout=60.0) for f in futs]
    x = np.stack([r.x for r in res])
    feasible = np.array([r.feasible for r in res])
    return apply_velocities(pos, x, feasible)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=256)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--direct", action="store_true",
                    help="fused single-batch path (no scheduler)")
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    # two opposing groups crossing (the classic stress test); grid spawn
    # with jitter guarantees initial clearance > 2r
    N = args.agents
    half = N // 2
    rows = int(np.ceil(np.sqrt(half)))

    def grid(x0):
        ij = np.stack(np.meshgrid(np.arange(rows), np.arange(rows)),
                      -1).reshape(-1, 2)[:half]
        p = ij * 1.0 + rng.uniform(-0.15, 0.15, (half, 2))
        p[:, 0] += x0
        p[:, 1] -= rows / 2
        return p

    pos = np.concatenate([grid(-12.0), grid(6.0)]).astype(np.float32)
    goal = np.concatenate([np.tile([9.0, 0.0], (half, 1)),
                           np.tile([-9.0, 0.0], (N - half, 1))]
                          ).astype(np.float32)
    sched = None
    if not args.direct:
        # The scheduler solves with the exact spec the direct path uses.
        sched = BatchScheduler(SPEC, max_batch=N)

    min_gap = np.inf
    for t in range(args.steps):
        if args.direct:
            pos = np.asarray(sim_step(jnp.asarray(pos), jnp.asarray(goal)))
        else:
            pos = sim_step_served(pos, goal, sched)
        if t % 20 == 0 or t == args.steps - 1:
            d = np.linalg.norm(pos[None] - pos[:, None], axis=-1)
            np.fill_diagonal(d, np.inf)
            min_gap = min(min_gap, d.min())
            prog = float(np.linalg.norm(goal - pos, axis=-1).mean())
            print(f"step {t:4d}: min pairwise distance {d.min():.3f} "
                  f"(2r = {2*RADIUS}), mean dist-to-goal {prog:.2f}")
    if sched is not None:
        print("[serve_lp] " + sched.metrics.format_report(
            sched.cache.stats()).replace("\n", "\n[serve_lp] "))
    print(f"done: worst clearance {min_gap:.3f} "
          f"({'NO collisions' if min_gap > 2*RADIUS*0.95 else 'contacts'})")


if __name__ == "__main__":
    main()
